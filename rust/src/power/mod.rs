//! Power and energy model (§III, Fig. 7, Tables I/VI/VIII).
//!
//! Activity-based: each switchable domain contributes
//! `P = P_leak(V) + Ceff·V²·f·activity`, with activities taken from the
//! simulator's counters (busy cores, HWCE occupancy, CWU duty) and every
//! coefficient anchored to a paper measurement ([`tables`]). Memory
//! traffic is charged per byte (Table VI). The [`pmu`] module exposes the
//! power-mode state machine of Fig. 7; [`EnergyLedger`] integrates energy
//! over an experiment.

pub mod pmu;
pub mod tables;

pub use pmu::{BootPath, LifecycleError, Pmu, PowerMode, WakeSource};
pub use tables::{OperatingPoint, HV, LV, NOM};

/// Cluster-domain power at operating point `op`.
///
/// * `core_util` — average fraction of the 9 cores actively clocking
///   (clock-gated cores at barriers don't switch).
/// * `hwce_active` — HWCE occupancy fraction.
pub fn cluster_power_w(op: OperatingPoint, core_util: f64, hwce_active: f64) -> f64 {
    let v2f = op.vdd * op.vdd * op.f_cl;
    let logic = tables::CLUSTER_CEFF
        * (tables::CLUSTER_IDLE_FRACTION
            + (1.0 - tables::CLUSTER_IDLE_FRACTION) * core_util.clamp(0.0, 1.0));
    let hwce = tables::CLUSTER_CEFF * tables::HWCE_CEFF_FRACTION * hwce_active.clamp(0.0, 1.0);
    tables::cluster_leak_w(op.vdd) + (logic + hwce) * v2f
}

/// SoC-domain power (FC + L2 + peripherals).
pub fn soc_power_w(op: OperatingPoint, fc_util: f64) -> f64 {
    let v2f = op.vdd * op.vdd * op.f_soc;
    let ceff = tables::SOC_CEFF
        * (tables::SOC_IDLE_FRACTION
            + (1.0 - tables::SOC_IDLE_FRACTION) * fc_util.clamp(0.0, 1.0));
    tables::soc_leak_w(op.vdd) + ceff * v2f
}

/// CWU power at clock `f_clk` with measured datapath duty factor `duty`
/// (Table I decomposition). `pads` folds in the SPI pad toggling — the
/// cognitive-sleep headline (1.7 µW) excludes pads, Table I's 2.97 µW
/// includes them.
pub fn cwu_power_w(f_clk: f64, duty: f64, pads: bool) -> f64 {
    let dp = tables::CWU_DATAPATH_W_PER_HZ * f_clk * (duty / tables::CWU_REF_DUTY).min(3.0);
    let pad = if pads { tables::CWU_PADS_W_PER_HZ * f_clk } else { 0.0 };
    tables::CWU_LEAK_W + dp + pad
}

/// L2 retention power for `bytes` of state-retentive SRAM (16 kB cuts).
pub fn retention_power_w(bytes: usize) -> f64 {
    if bytes == 0 {
        return 0.0;
    }
    let cuts = bytes.div_ceil(crate::soc::l2::RETENTION_CUT_BYTES);
    tables::RETENTION_FIRST_CUT_W + (cuts.saturating_sub(1)) as f64 * tables::RETENTION_PER_CUT_W
}

/// Energy integration over one experiment, split the way Fig. 11 reports
/// it (compute vs L2↔L1 vs L3 memory traffic).
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyLedger {
    pub compute_pj: f64,
    pub l2l1_pj: f64,
    pub l1_pj: f64,
    pub mram_pj: f64,
    pub hyperram_pj: f64,
}

impl EnergyLedger {
    /// Charge domain power over a time interval.
    pub fn add_compute(&mut self, power_w: f64, seconds: f64) {
        self.compute_pj += power_w * seconds * 1e12;
    }

    pub fn add_l2l1(&mut self, bytes: u64) {
        self.l2l1_pj += bytes as f64 * tables::PJ_PER_BYTE_L2L1;
    }

    pub fn add_l1(&mut self, bytes: u64) {
        self.l1_pj += bytes as f64 * tables::PJ_PER_BYTE_L1;
    }

    pub fn add_mram(&mut self, bytes: u64) {
        self.mram_pj += bytes as f64 * tables::PJ_PER_BYTE_MRAM;
    }

    pub fn add_hyperram(&mut self, bytes: u64) {
        self.hyperram_pj += bytes as f64 * tables::PJ_PER_BYTE_HYPERRAM;
    }

    pub fn total_pj(&self) -> f64 {
        self.compute_pj + self.l2l1_pj + self.l1_pj + self.mram_pj + self.hyperram_pj
    }

    pub fn total_mj(&self) -> f64 {
        self.total_pj() / 1e9
    }

    pub fn merge(&mut self, o: &EnergyLedger) {
        self.compute_pj += o.compute_pj;
        self.l2l1_pj += o.l2l1_pj;
        self.l1_pj += o.l1_pj;
        self.mram_pj += o.mram_pj;
        self.hyperram_pj += o.hyperram_pj;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::rel_err;

    #[test]
    fn cwu_matches_table1_totals() {
        // 2.97 µW @ 32 kHz, 14.9 µW @ 200 kHz (with pads, reference duty).
        let p32 = cwu_power_w(32e3, tables::CWU_REF_DUTY, true);
        let p200 = cwu_power_w(200e3, tables::CWU_REF_DUTY, true);
        assert!(rel_err(p32, 2.97e-6) < 0.02, "p32 = {p32}");
        assert!(rel_err(p200, 14.9e-6) < 0.02, "p200 = {p200}");
    }

    #[test]
    fn cognitive_sleep_is_1_7_uw() {
        // §III: 1.7 µW cognitive sleep = CWU running at 32 kHz, no pads
        // attributed (datapath + leakage).
        let p = cwu_power_w(32e3, tables::CWU_REF_DUTY, false);
        assert!(rel_err(p, 1.7e-6) < 0.03, "p = {p}");
    }

    #[test]
    fn retention_range_matches_table8() {
        // 16 kB → +1.1 µW; 1.6 MB → +(1.1 + 99×1.221) ≈ 122 µW.
        let lo = retention_power_w(16 * 1024);
        let hi = retention_power_w(1600 * 1024);
        assert!(rel_err(lo, 1.1e-6) < 0.01);
        assert!(rel_err(hi, 122e-6) < 0.02, "hi = {hi}");
        assert_eq!(retention_power_w(0), 0.0);
    }

    #[test]
    fn cluster_power_within_envelope() {
        // Full blast (8 cores + HWCE) at HV must stay within the 49.4 mW
        // power envelope of Table III/VIII.
        let p = cluster_power_w(HV, 1.0, 1.0) + soc_power_w(HV, 0.3);
        assert!(p < 49.4e-3 * 1.10, "p = {}", p * 1e3);
        assert!(p > 30e-3, "p = {}", p * 1e3);
    }

    #[test]
    fn lv_cluster_power_anchors_614_gops_per_w() {
        // ~7 GOPS at LV on int8 matmul at ≈614 GOPS/W ⇒ ≈11.5 mW.
        let p = cluster_power_w(LV, 1.0, 0.0) + soc_power_w(LV, 0.1);
        assert!(p > 8e-3 && p < 14e-3, "p = {}", p * 1e3);
    }

    #[test]
    fn idle_cluster_burns_much_less() {
        let idle = cluster_power_w(HV, 0.0, 0.0);
        let busy = cluster_power_w(HV, 1.0, 0.0);
        assert!(idle < 0.35 * busy);
    }

    #[test]
    fn ledger_accumulates_and_merges() {
        let mut e = EnergyLedger::default();
        e.add_mram(1000);
        e.add_hyperram(1000);
        assert!((e.mram_pj - 20e3).abs() < 1.0);
        assert!((e.hyperram_pj - 880e3).abs() < 1.0);
        e.add_compute(10e-3, 1e-3); // 10 µJ = 1e7 pJ
        assert!((e.compute_pj - 1e7).abs() < 1.0);
        let mut f = EnergyLedger::default();
        f.merge(&e);
        assert_eq!(f.total_pj(), e.total_pj());
    }
}
