//! Power-model calibration constants.
//!
//! Every constant is anchored to a measurement in the paper (cited per
//! line) or documented as an assumption. The activity-based model is
//! P = P_leak(V) + Ceff·V²·f·activity per switchable domain; DESIGN.md §8
//! lists the anchor points, `rust/tests/paper_anchors.rs` asserts that the
//! headline numbers *emerge* from simulation + this table within
//! tolerance.

/// An operating point of the SoC/cluster logic domains.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OperatingPoint {
    pub name: &'static str,
    pub vdd: f64,
    pub f_soc: f64,
    pub f_cl: f64,
}

/// Low-voltage point (Fig. 8 "LV"): 0.6 V, 220 MHz.
pub const LV: OperatingPoint =
    OperatingPoint { name: "LV", vdd: 0.6, f_soc: 220e6, f_cl: 220e6 };

/// Nominal DNN point (§IV-B): 0.8 V, 250 MHz.
pub const NOM: OperatingPoint =
    OperatingPoint { name: "NOM", vdd: 0.8, f_soc: 250e6, f_cl: 250e6 };

/// High-voltage point (Fig. 8 "HV"): 0.8 V, 450 MHz.
pub const HV: OperatingPoint =
    OperatingPoint { name: "HV", vdd: 0.8, f_soc: 450e6, f_cl: 450e6 };

/// Measured V/f curve anchors of the logic domains (Fig. 6b's DVFS
/// series): (Vdd, f) from the 0.5 V/120 MHz floor to the 0.8 V/450 MHz
/// peak. The single source of truth for every DVFS ladder — the Fig. 6b
/// reproduction and `vega sweep`'s interpolated operating points
/// ([`crate::sweep::explore::vf_hz`]) both read it.
pub const VF_ANCHORS: [(f64, f64); 4] =
    [(0.5, 120e6), (0.6, 220e6), (0.7, 330e6), (0.8, 450e6)];

/// DNN deployment point: 250 MHz with the cluster DVFS'd to 0.66 V.
/// §IV-B quotes Vdd_SOC = 0.8 V / 250 MHz; the measured MobileNetV2
/// energy (1.19 mJ over ~80 ms ⇒ ≈15 mW total) is only consistent with
/// the *cluster* domain running below 0.8 V at that frequency — at the
/// paper's own LV-calibrated Ceff, 0.8 V/250 MHz would burn ~23 mW. The
/// measured 1.19 mJ / >10 fps / 15.5 MAC-per-cycle triple is jointly
/// consistent with the cluster near 0.6 V at 250 MHz (220 MHz is the
/// spec point at 0.6 V; 250 is marginal-but-plausible silicon); this
/// calibration choice is documented in EXPERIMENTS.md.
pub const DNN: OperatingPoint =
    OperatingPoint { name: "DNN", vdd: 0.60, f_soc: 250e6, f_cl: 250e6 };

// ---------------------------------------------------------------------
// Cluster domain (9 cores + TCDM + interconnect + FPUs + HWCE).
// ---------------------------------------------------------------------

/// Effective switched capacitance of the full 8-core compute cluster at
/// 100% utilisation. Calibrated so the LV int8-matmul point lands at the
/// Table VIII anchor: ≈614 GOPS/W at ≈7 GOPS ⇒ ≈11.5 mW at 0.6 V/220 MHz.
pub const CLUSTER_CEFF: f64 = 132e-12;

/// Fraction of cluster Ceff that clocks even with idle (clock-gated)
/// cores: interconnect, shared I$, clock tree.
pub const CLUSTER_IDLE_FRACTION: f64 = 0.15;

/// HWCE effective capacitance relative to the cluster (27 MACs + streams;
/// far smaller than 8 cores — the accelerator-efficiency premise).
pub const HWCE_CEFF_FRACTION: f64 = 0.18;

/// Cluster-domain leakage (22 nm FD-SOI, poly-biased): measured-range
/// assumption anchored to the power floor of Fig. 6.
pub fn cluster_leak_w(vdd: f64) -> f64 {
    // Exponential-ish with voltage; 0.8 mW @ 0.6 V, 1.6 mW @ 0.8 V.
    0.8e-3 * (vdd / 0.6).powi(3)
}

// ---------------------------------------------------------------------
// SoC domain (FC + L2 + peripherals).
// ---------------------------------------------------------------------

/// SoC-domain Ceff at full FC activity. Anchored to §III: FC active mode
/// delivers 1.9 GOPS at 200 GOPS/W (≈9.5 mW) at HV.
pub const SOC_CEFF: f64 = 28e-12;

/// SoC domain share that clocks while the FC idles (L2 banks, I/O DMA,
/// peripheral bridge). §III floor: 0.7 mW SoC-active minimum.
pub const SOC_IDLE_FRACTION: f64 = 0.22;

pub fn soc_leak_w(vdd: f64) -> f64 {
    0.5e-3 * (vdd / 0.6).powi(3)
}

// ---------------------------------------------------------------------
// Always-on domain + sleep/retention (Table VIII, Fig. 7).
// ---------------------------------------------------------------------

/// Deep sleep floor (PMU + RTC + POR from VBAT): the 1.2 µW bottom of the
/// Table III power range.
pub const DEEP_SLEEP_W: f64 = 1.2e-6;

/// L2 retention: Table VIII "2.8–123.7 µW (16 kB–1.6 MB s.r.)" on top of
/// the 1.7 µW cognitive-sleep base ⇒ first cut 1.1 µW, then 1.22 µW/cut.
pub const RETENTION_FIRST_CUT_W: f64 = 1.1e-6;
pub const RETENTION_PER_CUT_W: f64 = 1.221e-6;

// ---------------------------------------------------------------------
// CWU (Table I).
// ---------------------------------------------------------------------

/// CWU datapath dynamic power per Hz of its clock, at the reference
/// workload (3×16-bit channels @ 150 SPS, language/EMG classification):
/// 0.99 µW @ 32 kHz and 6.21 µW @ 200 kHz ⇒ ~31 pW/kHz (linear ✓).
pub const CWU_DATAPATH_W_PER_HZ: f64 = 0.99e-6 / 32_000.0;

/// CWU SPI pad dynamic power per Hz: 1.28 µW @ 32 kHz (Table I).
pub const CWU_PADS_W_PER_HZ: f64 = 1.28e-6 / 32_000.0;

/// CWU leakage (UHVT logic at 0.6 V): 0.70 µW at both clock rates.
pub const CWU_LEAK_W: f64 = 0.70e-6;

/// Datapath duty factor of the reference workload the Table I numbers
/// were measured at (the dynamic term scales with measured duty). This is
/// the duty the simulated reference workload (3ch x 16-bit EMG HDC at
/// 150 SPS) actually produces — so the Table I datapath power is exact at
/// the reference point and scales with microcode complexity elsewhere.
pub const CWU_REF_DUTY: f64 = 0.178;

// ---------------------------------------------------------------------
// Memory access energies (Table VI; erratum-corrected, DESIGN.md §4).
// ---------------------------------------------------------------------

pub const PJ_PER_BYTE_HYPERRAM: f64 = 880.0;
pub const PJ_PER_BYTE_MRAM: f64 = 20.0;
pub const PJ_PER_BYTE_L2L1: f64 = 1.4;
pub const PJ_PER_BYTE_L1: f64 = 0.9;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cwu_table1_scaling_is_linear() {
        // The 200 kHz column must follow from the 32 kHz calibration.
        let dp_200k = CWU_DATAPATH_W_PER_HZ * 200_000.0;
        assert!((dp_200k - 6.21e-6).abs() / 6.21e-6 < 0.02, "dp = {dp_200k}");
        let pads_200k = CWU_PADS_W_PER_HZ * 200_000.0;
        assert!((pads_200k - 8.0e-6).abs() / 8.0e-6 < 0.02);
    }

    #[test]
    fn leakage_grows_with_voltage() {
        assert!(cluster_leak_w(0.8) > cluster_leak_w(0.6));
        assert!(soc_leak_w(0.8) > soc_leak_w(0.6));
    }

    #[test]
    fn operating_points_match_paper() {
        assert_eq!(LV.f_cl, 220e6);
        assert_eq!(HV.f_cl, 450e6);
        assert_eq!(NOM.f_cl, 250e6);
        assert_eq!(LV.vdd, 0.6);
        assert_eq!(HV.vdd, 0.8);
    }
}
