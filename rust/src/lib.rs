//! # Vega SoC reproduction library
//!
//! A cycle-approximate, energy-annotated full-system simulator of the Vega
//! IoT end-node SoC (Rossi et al., IEEE JSSC 2021), plus the PJRT runtime
//! bridge that executes the JAX/Pallas-authored DNN golden models from
//! `artifacts/`.
//!
//! The crate is organised bottom-up (see `DESIGN.md` for the full system
//! inventory):
//!
//! * [`isa`] / [`iss`] — RV32IMF+Xpulp instruction set, in-Rust assembler,
//!   and the per-core instruction-set simulator with the 4-stage timing
//!   model (load-use stalls, branch penalty, hardware loops), executed
//!   through three bit-identical speed tiers: reference scheduler, fast
//!   interpreter, and superblock trace replay (`PERFORMANCE.md`).
//! * [`cluster`] — the 9-core compute cluster: 16-bank word-interleaved L1
//!   TCDM behind a logarithmic interconnect, 4 shared FPUs with static
//!   core→FPU mapping, hierarchical instruction cache, event unit and
//!   cluster DMA.
//! * [`soc`] — the always-on/SoC domain: fabric controller, interleaved L2,
//!   I/O DMA (µDMA) channels.
//! * [`mem`] — non-volatile MRAM and external HyperRAM channel models.
//! * [`hwce`] — the Hardware Convolution Engine (multi-precision 3×3).
//! * [`cwu`] — the Cognitive Wake-Up unit: SPI sequencer, preprocessor and
//!   the Hypnos HDC engine.
//! * [`hdc`] — host-side hyperdimensional-computing training stack that
//!   programs Hypnos (prototype training, microcode generation, datasets).
//! * [`power`] — power domains, PMU state machine, activity-based energy
//!   ledger calibrated against the paper's measurements.
//! * [`kernels`] — the PULP-NN-style integer kernels and the eight FP NSAA
//!   kernels of Table V, authored as ISS instruction streams.
//! * [`dnn`] — layer graph IR, MobileNetV2 / RepVGG topologies, the
//!   DORY-style tiler and the four-stage double-buffered pipeline model.
//! * [`runtime`] — PJRT bridge loading `artifacts/*.hlo.txt`.
//! * [`faults`] — deterministic seeded fault-injection campaigns through
//!   the real SECDED/tier models, with per-tier corrected / detected /
//!   silent classification and fault-free-oracle divergence checks
//!   (`vega faults`).
//! * [`lifecycle`] — the trace-driven device-lifecycle engine: seeded
//!   sensor-event traces replayed through Fig. 7's sleep↔wake state
//!   machine, reporting battery lifetime, false-wake rate and per-state
//!   energy (`vega lifecycle`).
//! * [`sweep`] — the sweep execution engine: memoized, parallel scenario
//!   fan-out behind the reproduction suite (`vega repro --jobs N`), the
//!   persistent on-disk simulation store shared across processes
//!   ([`sweep::persist`]) and the design-space exploration grids of
//!   `vega sweep` ([`sweep::explore`]).
//! * [`coordinator`] / [`bench`] — experiment drivers regenerating every
//!   table and figure of the paper's evaluation.
//!
//! `README.md` is the newcomer entry point; `ARCHITECTURE.md` maps the
//! sweep/exploration subsystem across modules; `PERFORMANCE.md` collects
//! the host-performance architecture (what makes the simulator fast and
//! the invariant that keeps each layer honest).

// The whole simulator is safe Rust by construction (guest memory is
// Vec-backed, no FFI outside the gated PJRT bridge) — enforce it so a
// future accelerator model can't quietly reach for raw pointers.
#![forbid(unsafe_code)]

// missing_docs triage (ISSUE 3 rustdoc pass): the exploration-facing
// surface (`sweep`, `bench`, `coordinator`, `cwu`, `kernels`) carries
// full doc comments and `scripts/ci.sh` gates `cargo doc` warnings
// (broken links, bad html) as fatal. `#![warn(missing_docs)]` itself
// stays off for now: the ISS/cluster internals expose many
// self-describing counter/register fields whose one-line restatements
// would be noise; revisit if the crate ever grows external consumers.

pub mod bench;
pub mod cluster;
pub mod common;
pub mod coordinator;
pub mod cwu;
pub mod dnn;
pub mod faults;
pub mod hdc;
pub mod hwce;
pub mod isa;
pub mod iss;
pub mod kernels;
pub mod lifecycle;
pub mod mem;
pub mod power;
pub mod runtime;
pub mod soc;
pub mod sweep;

pub use common::{Cycles, PicoJoules, VegaError};
