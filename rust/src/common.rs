//! Shared primitive types: cycles, energy, frequencies, errors, and the
//! deterministic PRNG used throughout the simulator and the hand-rolled
//! property-testing helper (proptest is unavailable offline; see
//! DESIGN.md §5 substitutions).

/// Clock cycles of whichever domain is being discussed.
pub type Cycles = u64;

/// Energy in picojoules. All per-event energies in the power model are
/// picojoule-denominated (Table VI is given in pJ/B).
pub type PicoJoules = f64;

/// Frequency in Hz.
pub type Hertz = f64;

/// Crate-wide error type (hand-rolled Display/Error impls: thiserror is
/// unavailable offline, DESIGN.md §5 substitutions).
#[derive(Debug)]
pub enum VegaError {
    Asm(String),
    Sim(String),
    Config(String),
    Runtime(String),
    Io(std::io::Error),
}

impl std::fmt::Display for VegaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            VegaError::Asm(s) => write!(f, "assembler error: {s}"),
            VegaError::Sim(s) => write!(f, "simulation error: {s}"),
            VegaError::Config(s) => write!(f, "configuration error: {s}"),
            VegaError::Runtime(s) => write!(f, "runtime error: {s}"),
            VegaError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for VegaError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            VegaError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for VegaError {
    fn from(e: std::io::Error) -> Self {
        VegaError::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, VegaError>;

/// xorshift64* — deterministic, seedable, dependency-free PRNG.
///
/// Used for synthetic weights/activations, sensor waveform generation and
/// the property-test helper. Not cryptographic; determinism across runs is
/// the requirement here (EXPERIMENTS.md records seeds).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point.
        Self { state: seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1 }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + (self.below((hi - lo + 1) as u64) as i64)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[-1, 1)`.
    pub fn f32_pm1(&mut self) -> f32 {
        (self.f64() * 2.0 - 1.0) as f32
    }

    /// Random i8 over the full range (an int8 tensor element).
    pub fn i8(&mut self) -> i8 {
        self.next_u64() as i8
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// A random bit-vector of `bits` bits packed into u64 words.
    pub fn bitvec(&mut self, bits: usize) -> Vec<u64> {
        let words = bits.div_ceil(64);
        let mut v: Vec<u64> = (0..words).map(|_| self.next_u64()).collect();
        let tail = bits % 64;
        if tail != 0 {
            v[words - 1] &= (1u64 << tail) - 1;
        }
        v
    }
}

/// Minimal property-test driver: runs `f` on `n` seeded cases; panics with
/// the failing case index + seed so the case can be replayed exactly.
pub fn property(name: &str, n: u64, mut f: impl FnMut(&mut Rng)) {
    for case in 0..n {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {case} (seed={seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// FNV-1a 64-bit hash accumulator — the crate's one pinned hash algorithm
/// (shared by [`crate::isa::Program::content_hash`] and the sweep cache's
/// output digests). Implemented as a [`std::hash::Hasher`] so derived
/// `Hash` impls can feed it.
#[derive(Debug, Clone)]
pub struct Fnv1a(u64);

impl Fnv1a {
    pub fn new() -> Self {
        Self(0xcbf2_9ce4_8422_2325)
    }
}

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

impl std::hash::Hasher for Fnv1a {
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

/// Little-endian byte-stream writer: the crate's one way to produce
/// *persistable* bytes (std-only; serde is unavailable offline).
///
/// Every on-disk artifact — cache keys, [`crate::sweep::DiskStore`]
/// entries, the explicit ISA/DNN encodings — is written through these
/// primitives, so the byte layout is defined here, by this code, and
/// never by a derived impl whose layout the toolchain may change.
#[derive(Debug, Default)]
pub struct ByteWriter(Vec<u8>);

impl ByteWriter {
    pub fn new() -> Self {
        Self(Vec::new())
    }

    pub fn with_capacity(n: usize) -> Self {
        Self(Vec::with_capacity(n))
    }

    pub fn u8(&mut self, v: u8) {
        self.0.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    /// f64 by IEEE bit pattern (bit-exact round trip, NaNs included).
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Length-prefixed (u32 LE) UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.0.extend_from_slice(s.as_bytes());
    }

    pub fn bytes(&mut self, b: &[u8]) {
        self.0.extend_from_slice(b);
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn into_vec(self) -> Vec<u8> {
        self.0
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.0
    }
}

/// Bounds-checked reader over a [`ByteWriter`]-produced stream. Every
/// accessor returns `None` past the end — callers treat that as a cache
/// miss, never a panic.
#[derive(Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        if end > self.buf.len() {
            return None;
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Some(s)
    }

    pub fn u8(&mut self) -> Option<u8> {
        Some(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Option<u32> {
        Some(u32::from_le_bytes(self.take(4)?.try_into().ok()?))
    }

    pub fn u64(&mut self) -> Option<u64> {
        Some(u64::from_le_bytes(self.take(8)?.try_into().ok()?))
    }

    pub fn f64(&mut self) -> Option<f64> {
        Some(f64::from_bits(self.u64()?))
    }

    pub fn str(&mut self) -> Option<String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec()).ok()
    }

    /// Has every byte been consumed? (Trailing garbage = corrupt entry.)
    pub fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

/// Pretty-print a byte count.
pub fn fmt_bytes(b: usize) -> String {
    if b >= 1 << 20 {
        format!("{:.1} MB", b as f64 / (1 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.1} kB", b as f64 / (1 << 10) as f64)
    } else {
        format!("{b} B")
    }
}

/// Relative error |got - want| / |want| (for calibration assertions).
pub fn rel_err(got: f64, want: f64) -> f64 {
    if want == 0.0 {
        got.abs()
    } else {
        (got - want).abs() / want.abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
            let v = r.range_i64(-5, 5);
            assert!((-5..=5).contains(&v));
        }
    }

    #[test]
    fn rng_f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut acc = 0.0;
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            acc += x;
        }
        // Mean should be near 0.5 for a uniform source.
        assert!((acc / 1000.0 - 0.5).abs() < 0.05);
    }

    #[test]
    fn bitvec_tail_is_masked() {
        let mut r = Rng::new(9);
        let v = r.bitvec(70);
        assert_eq!(v.len(), 2);
        assert_eq!(v[1] >> 6, 0);
    }

    #[test]
    fn fnv1a_matches_reference_vectors() {
        use std::hash::Hasher;
        // Published FNV-1a 64-bit vectors: "" and "a".
        assert_eq!(Fnv1a::new().finish(), 0xcbf2_9ce4_8422_2325);
        let mut h = Fnv1a::new();
        h.write(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn byte_stream_round_trips_and_bounds_checks() {
        let mut w = ByteWriter::new();
        w.u8(0xAB);
        w.u32(0xDEAD_BEEF);
        w.u64(0x0123_4567_89AB_CDEF);
        w.f64(-0.5);
        w.str("vega");
        let bytes = w.into_vec();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.u8(), Some(0xAB));
        assert_eq!(r.u32(), Some(0xDEAD_BEEF));
        assert_eq!(r.u64(), Some(0x0123_4567_89AB_CDEF));
        assert_eq!(r.f64(), Some(-0.5));
        assert_eq!(r.str().as_deref(), Some("vega"));
        assert!(r.done());
        assert_eq!(r.u8(), None, "reads past the end are None, not panics");
        // A truncated stream fails cleanly mid-field.
        let mut t = ByteReader::new(&bytes[..bytes.len() - 1]);
        t.u8();
        t.u32();
        t.u64();
        t.f64();
        assert_eq!(t.str(), None);
    }

    #[test]
    fn rel_err_basics() {
        assert!(rel_err(1.0, 1.0) == 0.0);
        assert!((rel_err(1.1, 1.0) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn property_runs_all_cases() {
        let mut count = 0;
        property("count", 10, |_| count += 1);
        assert_eq!(count, 10);
    }
}
