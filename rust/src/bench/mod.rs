//! Reproduction generators: one entry per table and figure of the paper's
//! evaluation (DESIGN.md §4 experiment index). Each returns the rendered
//! report; `vega repro <id>` prints it, the cargo benches time it, and
//! `paper_anchors` integration tests assert the numbers inside.

pub mod ablations;
pub mod figures;
pub mod tables;

/// All reproduction ids in paper order.
pub const ALL: [&str; 13] = [
    "table1", "table2", "table3", "table4", "table5", "table6", "table7", "table8", "fig6",
    "fig7", "fig8", "fig9", "fig10",
];

/// Extended list including fig11 (same driver as fig10's totals) and the
/// design-choice ablations.
pub const ALL_WITH_FIG11: [&str; 16] = [
    "table1", "table2", "table3", "table4", "table5", "table6", "table7", "table8", "fig6",
    "fig7", "fig8", "fig9", "fig10", "fig11", "ablations", "bootmodel",
];

/// Run one reproduction by id.
pub fn run(id: &str) -> Option<String> {
    Some(match id {
        "table1" => tables::table1(),
        "table2" => tables::table2(),
        "table3" => tables::table3(),
        "table4" => tables::table4(),
        "table5" => tables::table5(),
        "table6" => tables::table6(),
        "table7" => tables::table7(),
        "table8" => tables::table8(),
        "fig6" => figures::fig6(),
        "fig7" => figures::fig7(),
        "fig8" => figures::fig8(),
        "fig9" => figures::fig9(),
        "fig10" => figures::fig10(),
        "fig11" => figures::fig11(),
        "ablations" => ablations::ablations(),
        "bootmodel" => figures::bootmodel(),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    #[test]
    fn unknown_id_is_none() {
        assert!(super::run("table99").is_none());
    }

    #[test]
    fn cheap_reports_render() {
        // The static/cheap ones (full sweeps are covered by integration
        // tests and the benches).
        for id in ["table2", "table3", "table4", "table6", "fig7", "bootmodel"] {
            let r = super::run(id).unwrap();
            assert!(r.len() > 100, "{id} report too short");
        }
    }
}
