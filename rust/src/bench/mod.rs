//! Reproduction generators: one entry per table and figure of the paper's
//! evaluation (DESIGN.md §4 experiment index). Each returns the rendered
//! report; `vega repro <id> [--jobs N]` prints it, the cargo benches time
//! it, and `paper_anchors` integration tests assert the numbers inside.
//!
//! All simulation-backed reports pull their kernel runs through a
//! [`SweepEngine`], so V/f sweeps simulate each distinct program once and
//! a whole-suite run (`vega repro all`) shares matmul simulations across
//! tables and figures. Reports are byte-identical for any worker count
//! (`tests/sweep_determinism.rs`).

pub mod ablations;
pub mod figures;
pub mod tables;

use crate::sweep::{Scenario, SweepEngine};

/// All reproduction ids in paper order.
pub const ALL: [&str; 13] = [
    "table1", "table2", "table3", "table4", "table5", "table6", "table7", "table8", "fig6",
    "fig7", "fig8", "fig9", "fig10",
];

/// Extended list including fig11 (same driver as fig10's totals) and the
/// design-choice ablations.
pub const ALL_WITH_FIG11: [&str; 16] = [
    "table1", "table2", "table3", "table4", "table5", "table6", "table7", "table8", "fig6",
    "fig7", "fig8", "fig9", "fig10", "fig11", "ablations", "bootmodel",
];

/// Run one reproduction by id on the process-wide shared engine
/// ([`SweepEngine::global`]).
///
/// Compatibility entry point: identical output to [`run_with`] on any
/// engine (the determinism invariant). Repeated per-id calls in one
/// process — and, through the engine's on-disk store, repeated CLI
/// invocations of the same id across processes — reuse cached cycle
/// results instead of rebuilding Cluster/L2 state per call. Callers that
/// need an isolated cache (timing baselines, counter assertions) should
/// use [`run_with`] on their own engine.
pub fn run(id: &str) -> Option<String> {
    run_with(id, SweepEngine::global())
}

/// Run one reproduction by id, pulling simulations through `eng`.
///
/// Prefetches the report's scenario grid through the engine's worker
/// pool first, so `vega repro <id> --jobs N` parallelises even for a
/// single report; the render then reads cache hits. With memoization
/// off (the bench's no-cache baseline) a prefetch would just simulate
/// everything twice, so it is skipped.
pub fn run_with(id: &str, eng: &SweepEngine) -> Option<String> {
    if eng.cache().enabled() {
        eng.run_scenarios(&scenarios_for(id));
    }
    render(id, eng)
}

/// Render one report from the engine's (already warm or warming) caches,
/// without a prefetch fan-out — the path `SweepEngine::render_reports`
/// workers use, so report-level parallelism never nests a second
/// scenario-level thread pool per worker.
pub(crate) fn render(id: &str, eng: &SweepEngine) -> Option<String> {
    Some(match id {
        "table1" => tables::table1(eng),
        "table2" => tables::table2(),
        "table3" => tables::table3(),
        "table4" => tables::table4(),
        "table5" => tables::table5(eng),
        "table6" => tables::table6(),
        "table7" => tables::table7(eng),
        "table8" => tables::table8(eng),
        "fig6" => figures::fig6(eng),
        "fig7" => figures::fig7(),
        "fig8" => figures::fig8(eng),
        "fig9" => figures::fig9(eng),
        "fig10" => figures::fig10(eng),
        "fig11" => figures::fig11(eng),
        "ablations" => ablations::ablations(eng),
        "bootmodel" => figures::bootmodel(),
        _ => return None,
    })
}

/// The scenario grid a report id simulates (empty for analytic/static
/// reports). Used to prefetch the union of a suite's simulations through
/// the worker pool before the reports themselves render.
pub fn scenarios_for(id: &str) -> Vec<Scenario> {
    match id {
        "table5" => tables::table5_scenarios(),
        "table8" => tables::table8_scenarios(),
        "fig6" => figures::fig6_scenarios(),
        "fig8" => figures::fig8_scenarios(),
        "ablations" => ablations::ablation_scenarios(),
        _ => Vec::new(),
    }
}

/// Run a list of reproductions through one engine: prefetch the union of
/// their scenario grids (fine-grained parallel fan-out, deduplicated by
/// the cache), then render the reports (coarse-grained fan-out). Output
/// order is `ids` order regardless of completion order; unknown ids yield
/// `None`.
pub fn run_many(ids: &[&str], eng: &SweepEngine) -> Vec<Option<String>> {
    if eng.cache().enabled() {
        // Dedup by canonical scenario so no worker stalls on a slot lock
        // behind a duplicate's in-flight simulation.
        let mut seen = std::collections::HashSet::new();
        let union: Vec<Scenario> = ids
            .iter()
            .flat_map(|id| scenarios_for(id))
            .map(Scenario::canonical)
            .filter(|s| seen.insert(*s))
            .collect();
        eng.run_scenarios(&union);
    }
    eng.render_reports(ids)
}

/// Run the full [`ALL_WITH_FIG11`] suite through one engine (the
/// `vega repro all` body): matmul programs recurring across tables and
/// figures are simulated once. Returns the concatenated reports in paper
/// order, one trailing newline per report (matching the CLI's `println!`
/// framing).
pub fn run_all(eng: &SweepEngine) -> String {
    run_many(&ALL_WITH_FIG11, eng)
        .into_iter()
        .map(|r| {
            let mut s = r.expect("known id");
            s.push('\n');
            s
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_is_none() {
        assert!(super::run("table99").is_none());
        assert!(run_with("table99", &SweepEngine::serial()).is_none());
    }

    #[test]
    fn cheap_reports_render() {
        // The static/cheap ones (full sweeps are covered by integration
        // tests and the benches).
        for id in ["table2", "table3", "table4", "table6", "fig7", "bootmodel"] {
            let r = super::run(id).unwrap();
            assert!(r.len() > 100, "{id} report too short");
        }
    }

    #[test]
    fn every_id_declares_its_grid() {
        // Simulation-backed reports expose non-empty scenario lists; the
        // analytic ones are (and must stay) empty rather than panicking.
        for id in ALL_WITH_FIG11 {
            let grid = scenarios_for(id);
            match id {
                "table5" | "table8" | "fig6" | "fig8" | "ablations" => {
                    assert!(!grid.is_empty(), "{id} lost its scenario grid")
                }
                _ => assert!(grid.is_empty(), "{id} unexpectedly simulates"),
            }
        }
    }
}
