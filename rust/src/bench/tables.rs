//! Table reproductions (Tables I–VIII of the paper).

use crate::coordinator::report::{f1, f2, si_power, Table};
use crate::coordinator::{self, NSAA_KERNELS};
use crate::cwu::CWU_AREA_MM2;
use crate::dnn::{self, repvgg, PipelineConfig, StorePolicy, Variant};
use crate::kernels::fp_matmul::FpWidth;
use crate::kernels::int_matmul::IntWidth;
use crate::mem::BulkChannel;
use crate::power::{self, tables as pt};
use crate::sweep::{Scenario, SweepEngine};

/// Table I: CWU implementation details and power at 32 kHz / 200 kHz.
/// The reference workload (HDC-training-dominated) is memoized on the
/// engine, so repeated renders train once per process.
pub fn table1(eng: &SweepEngine) -> String {
    let mut t = Table::new(
        "Table I - CWU power (measured workload: 3ch x 16-bit HDC classification)",
        &["", "f_clk = 32 kHz", "f_clk = 200 kHz"],
    );
    let run = eng.cwu_summary(32_000.0);
    let duty = run.duty_at_150sps;
    // Max sample rate: datapath cycles/frame plus the SPI acquisition
    // (3 x 18 clocks at an SPI clock of f_clk/2 => x2 in system cycles).
    let cpf = run.datapath_cycles as f64 / run.frames as f64 + (3.0 * 18.0) * 2.0;
    let max_sps_32k = 32_000.0 / cpf;
    let max_sps_200k = 200_000.0 / cpf;
    let dp32 = pt::CWU_DATAPATH_W_PER_HZ * 32e3 * (duty / pt::CWU_REF_DUTY).min(3.0);
    let dp200 = pt::CWU_DATAPATH_W_PER_HZ * 200e3 * (duty / pt::CWU_REF_DUTY).min(3.0);
    let pads32 = pt::CWU_PADS_W_PER_HZ * 32e3;
    let pads200 = pt::CWU_PADS_W_PER_HZ * 200e3;
    t.row(&[
        "Max. Samp. Rate".into(),
        format!("{:.0} SPS/ch", max_sps_32k),
        format!("{:.0} SPS/ch", max_sps_200k),
    ]);
    t.row(&["P_dyn datapath".into(), si_power(dp32), si_power(dp200)]);
    t.row(&["P_dyn SPI pads".into(), si_power(pads32), si_power(pads200)]);
    t.row(&[
        "P_leak datapath".into(),
        si_power(pt::CWU_LEAK_W),
        si_power(pt::CWU_LEAK_W),
    ]);
    t.row(&[
        "P_total".into(),
        si_power(dp32 + pads32 + pt::CWU_LEAK_W),
        si_power(dp200 + pads200 + pt::CWU_LEAK_W),
    ]);
    t.row(&[
        "(workload accuracy)".into(),
        format!("{:.0} %", run.accuracy * 100.0),
        "-".into(),
    ]);
    format!(
        "{}\npaper: 150/1000 SPS; 0.99/6.21 uW dp; 1.28/8.00 uW pads; 0.70 uW leak; 2.97/14.9 uW total\n",
        t.render()
    )
}

/// Table II: smart wake-up unit comparison (our CWU measured; the
/// published rows quoted as constants).
pub fn table2() -> String {
    let mut t = Table::new(
        "Table II - state-of-the-art smart wake-up units",
        &["Design", "Application", "Tech", "Power", "Scheme", "Area"],
    );
    let rows: [[&str; 6]; 4] = [
        ["Cho2019 [12]", "VAD", "180nm", "14 uW", "NN", "~3.7 mm2"],
        ["Giraldo2020 [24]", "KWS", "65nm", "2 uW", "LSTM/GMM", "~0.4 mm2"],
        ["Wang2020 [25]", "Slope match", "180nm", "17 nW", "Threshold", "~1.8 mm2"],
        ["Rovere2018 [26]", "General", "130nm", "2.2 uW", "Thr. seq.", "0.011 mm2"],
    ];
    for r in rows {
        t.row(&r.map(String::from));
    }
    let p = power::cwu_power_w(32e3, pt::CWU_REF_DUTY, true);
    t.row(&[
        "Vega CWU (this sim)".into(),
        "General".into(),
        "22nm".into(),
        si_power(p),
        "HDC".into(),
        format!("{CWU_AREA_MM2} mm2"),
    ]);
    format!("{}\npaper Vega row: 2.97 uW, HDC, 0.147 mm2\n", t.render())
}

/// Table III: SoC features (static configuration, cross-checked against
/// model parameters).
pub fn table3() -> String {
    let mut t = Table::new("Table III - Vega SoC features", &["Feature", "Value"]);
    let rows = [
        ("Technology", "CMOS 22nm FD-SOI".to_string()),
        ("Chip Area", "12 mm2".to_string()),
        (
            "SRAM Memory",
            format!("{} kB", (crate::soc::l2::L2_SIZE + crate::cluster::TCDM_SIZE) / 1024),
        ),
        ("MRAM Memory", format!("{} MB", crate::mem::mram::MRAM_SIZE / (1024 * 1024))),
        ("Voltage Range", "0.6 V - 0.8 V".to_string()),
        ("Frequency Range", "32 kHz - 450 MHz".to_string()),
        (
            "Power Range",
            format!(
                "{} - {}",
                si_power(pt::DEEP_SLEEP_W),
                si_power(
                    power::cluster_power_w(power::HV, 1.0, 1.0)
                        + power::soc_power_w(power::HV, 0.3)
                )
            ),
        ),
    ];
    for (k, v) in rows {
        t.row(&[k.into(), v]);
    }
    format!("{}\npaper: 1728 kB SRAM, 4 MB MRAM, 1.2 uW - 49.4 mW\n", t.render())
}

/// Table IV: area breakdown (published layout data; percentage column
/// recomputed as a consistency check).
pub fn table4() -> String {
    let rows: [(&str, f64); 10] = [
        ("MRAM", 3.59),
        ("SoC Domain", 2.69),
        ("Cluster Domain", 1.48),
        ("CWU", 0.14),
        ("CSI2", 0.15),
        ("DCDC1", 0.36),
        ("DCDC2", 0.36),
        ("POR", 0.14),
        ("QOSC", 0.03),
        ("LDO", 0.03),
    ];
    let total = 12.0;
    let mut t = Table::new("Table IV - area breakdown", &["Instance", "mm2", "%"]);
    for (name, a) in rows {
        t.row(&[name.into(), f2(a), f1(a / total * 100.0)]);
    }
    let accel: f64 = 1.48 + 0.14;
    format!(
        "{}\ncheck: programmable accelerators = {:.1}% of die (paper: <15%)\n",
        t.render(),
        accel / total * 100.0
    )
}

/// The Table V scenario grid: every NSAA kernel at FP32 on 8 cores.
pub fn table5_scenarios() -> Vec<Scenario> {
    NSAA_KERNELS.iter().map(|&name| Scenario::Nsaa { name, w: FpWidth::F32 }).collect()
}

/// Table V: benchmark suite FP intensity — *measured* from the executed
/// instruction streams of our kernels.
pub fn table5(eng: &SweepEngine) -> String {
    let paper = [57, 55, 28, 63, 64, 46, 83, 35];
    let mut t = Table::new(
        "Table V - FP NSAA suite, FP intensity (measured on the ISS)",
        &["Kernel", "measured %", "paper %"],
    );
    // Per-row cache lookups (not a nested run_scenarios fan-out: under
    // `repro all` the grid is already prefetched, and report workers must
    // not spawn second-level thread pools just to read cache hits).
    let mut avg = 0.0;
    for (&name, p) in NSAA_KERNELS.iter().zip(paper) {
        let kr = eng.kernel_run(Scenario::Nsaa { name, w: FpWidth::F32 });
        let fi = kr.fp_intensity() * 100.0;
        avg += fi;
        t.row(&[name.to_string(), f1(fi), p.to_string()]);
    }
    avg /= NSAA_KERNELS.len() as f64;
    format!("{}\naverage: {:.0}% (paper: 53%)\n", t.render(), avg)
}

/// Table VI: transfer channels — bandwidth emergent from the channel
/// models, energy from the (erratum-corrected) coefficients.
pub fn table6() -> String {
    let f = 250e6;
    let bytes = 1u64 << 20;
    let mram = crate::mem::Mram::new();
    let hyper = crate::mem::HyperRam::new(16 << 20);
    let mbps = |cycles: u64| bytes as f64 / (cycles as f64 / f) / 1e6;
    let mut t = Table::new(
        "Table VI - data transfer channels (1 MB transfer @ 250 MHz)",
        &["Channel", "Bandwidth [MB/s]", "Energy [pJ/B]"],
    );
    t.row(&[
        "HyperRAM <-> L2".into(),
        f1(mbps(hyper.transfer_cycles(bytes, f, false))),
        f1(pt::PJ_PER_BYTE_HYPERRAM),
    ]);
    t.row(&[
        "MRAM -> L2".into(),
        f1(mbps(mram.transfer_cycles(bytes, f, false))),
        f1(pt::PJ_PER_BYTE_MRAM),
    ]);
    let l2l1 = crate::cluster::ClusterDma::sustained_bpc(crate::cluster::DmaJob::linear(
        bytes,
    )) * f
        / 1e6;
    t.row(&["L2 <-> L1".into(), f1(l2l1), f1(pt::PJ_PER_BYTE_L2L1)]);
    t.row(&["L1 access".into(), "8000".into(), f1(pt::PJ_PER_BYTE_L1)]);
    format!(
        "{}\npaper (rows erratum-corrected, DESIGN.md §4): 200/300/1900/8000 MB/s; 880/20/1.4/0.9 pJ/B\n",
        t.render()
    )
}

/// Table VII: RepVGG-A0/A1/A2, software vs HWCE.
pub fn table7(eng: &SweepEngine) -> String {
    let mut t = Table::new(
        "Table VII - RepVGG on Vega (SW @250MHz vs HWCE @450MHz, greedy MRAM)",
        &[
            "Net", "Top-1 %", "SW ms", "HWCE ms", "speedup", "SW mJ", "HWCE mJ", "eff gain",
            "MMAC", "param KB", "MRAM up to",
        ],
    );
    for v in [Variant::A0, Variant::A1, Variant::A2] {
        let net = repvgg(v);
        let sw = eng.network_report(&net, PipelineConfig::nominal_sw(StorePolicy::GreedyMram));
        let hw = eng.network_report(&net, PipelineConfig::table7_hwce(StorePolicy::GreedyMram));
        let speedup = sw.latency_s() / hw.latency_s();
        let gain = (sw.energy_mj() / hw.energy_mj() - 1.0) * 100.0;
        let split = hw
            .mram_up_to
            .map(|i| net.layers[i].name.clone())
            .unwrap_or_else(|| "all".into());
        t.row(&[
            v.name().into(),
            f2(v.top1()),
            f1(sw.latency_s() * 1e3),
            f1(hw.latency_s() * 1e3),
            format!("{:.2}x", speedup),
            f1(sw.energy_mj()),
            f1(hw.energy_mj()),
            format!("+{:.0}%", gain),
            format!("{:.0}", net.total_macs() as f64 / 1e6),
            format!("{:.0}", net.total_weight_bytes() as f64 / 1024.0),
            split,
        ]);
    }
    format!(
        "{}\npaper: A0 358/118 ms (3.03x) 8.5/4.4 mJ (+93%); A1 610/200 (3.05x) 13.0/7.4 (+76%); A2 1320/433 (3.05x) 25.7/15.8 (+63%)\n",
        t.render()
    )
}

/// The Table VIII scenario grid: the three 8-core matmul headliners (the
/// HV and LV rows derive from the same cached simulations analytically).
pub fn table8_scenarios() -> Vec<Scenario> {
    vec![
        Scenario::IntMatmul { w: IntWidth::I8, cores: 8 },
        Scenario::FpMatmul { w: FpWidth::F32, cores: 8 },
        Scenario::FpMatmul { w: FpWidth::F16x2, cores: 8 },
    ]
}

/// Table VIII: comparison with the state of the art — the Vega column
/// measured from this simulator, the published columns as constants.
pub fn table8(eng: &SweepEngine) -> String {
    // Measured Vega numbers (one simulation per scenario; both operating
    // points read the same cached cycle counts).
    let i8_hv = eng.kernel_run(Scenario::IntMatmul { w: IntWidth::I8, cores: 8 });
    let (int_perf, _) = coordinator::efficiency(&i8_hv, power::HV, 0.0);
    let (int_perf_lv, int_eff) = coordinator::efficiency(&i8_hv, power::LV, 0.0);
    let f32_run = eng.kernel_run(Scenario::FpMatmul { w: FpWidth::F32, cores: 8 });
    let (fp32_perf, _) = coordinator::efficiency(&f32_run, power::HV, 0.0);
    let (_, fp32_eff) = coordinator::efficiency(&f32_run, power::LV, 0.0);
    let f16_run = eng.kernel_run(Scenario::FpMatmul { w: FpWidth::F16x2, cores: 8 });
    let (fp16_perf, _) = coordinator::efficiency(&f16_run, power::HV, 0.0);
    let (_, fp16_eff) = coordinator::efficiency(&f16_run, power::LV, 0.0);
    // Peak ML = SW + HWCE hybrid on a RepVGG stage at HV.
    let net = repvgg(Variant::A0);
    let hy = eng.network_report(
        &net,
        crate::dnn::PipelineConfig {
            op: power::HV,
            engine: dnn::Engine::HwceHybrid,
            policy: StorePolicy::GreedyMram,
        },
    );
    let ml_gops = hy.mac_per_cycle() * 2.0 * power::HV.f_cl / 1e9;
    let ml_power = power::cluster_power_w(power::LV, 1.0, 1.0) + power::soc_power_w(power::LV, 0.1);
    let ml_eff_tops = hy.mac_per_cycle() * 2.0 * power::LV.f_cl / 1e9 / ml_power / 1000.0;

    let mut t = Table::new(
        "Table VIII - SoA comparison (Vega column measured on this simulator)",
        &["Metric", "Mr.Wolf", "GAP8", "SamurAI", "Vega (paper)", "Vega (sim)"],
    );
    t.row(&[
        "Best INT8 perf [GOPS]".into(),
        "12.1".into(),
        "6".into(),
        "1.5".into(),
        "15.6".into(),
        f1(int_perf),
    ]);
    t.row(&[
        "Best INT8 eff [GOPS/W]".into(),
        "190".into(),
        "79".into(),
        "230".into(),
        "614".into(),
        format!("{:.0} @ {:.1} GOPS", int_eff, int_perf_lv),
    ]);
    t.row(&[
        "Best FP32 perf [GFLOPS]".into(),
        "1".into(),
        "-".into(),
        "-".into(),
        "2".into(),
        f2(fp32_perf),
    ]);
    t.row(&[
        "Best FP32 eff [GFLOPS/W]".into(),
        "18".into(),
        "-".into(),
        "-".into(),
        "79".into(),
        format!("{:.0}", fp32_eff),
    ]);
    t.row(&[
        "Best FP16 perf [GFLOPS]".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "3.3".into(),
        f2(fp16_perf),
    ]);
    t.row(&[
        "Best FP16 eff [GFLOPS/W]".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "129".into(),
        format!("{:.0}", fp16_eff),
    ]);
    t.row(&[
        "Best ML perf [GOPS]".into(),
        "-".into(),
        "12".into(),
        "36".into(),
        "32.2".into(),
        f1(ml_gops),
    ]);
    t.row(&[
        "Best ML eff [TOPS/W]".into(),
        "-".into(),
        "0.2".into(),
        "1.3".into(),
        "1.3".into(),
        f2(ml_eff_tops),
    ]);
    t.row(&[
        "Sleep power (CWU)".into(),
        "72 uW".into(),
        "3.6 uW".into(),
        "6.4 uW".into(),
        "1.7 uW".into(),
        si_power(power::cwu_power_w(32e3, pt::CWU_REF_DUTY, false)),
    ]);
    t.row(&[
        "Retentive sleep (1.6MB)".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "123.7 uW".into(),
        si_power(
            power::PowerMode::CognitiveSleep { retentive_l2_bytes: 1600 * 1024 }.power_w(),
        ),
    ]);
    t.render()
}
