//! Figure reproductions (Figs. 6–11): the same series the paper plots,
//! printed as data tables.

use crate::coordinator::report::{f1, f2, si_power, Table};
use crate::coordinator::{self, NSAA_KERNELS};
use crate::dnn::{mobilenet_v2, Bound, PipelineConfig, StorePolicy};
use crate::kernels::fp_matmul::FpWidth;
use crate::kernels::int_matmul::IntWidth;
use crate::power::{self, tables as pt};
use crate::sweep::{Scenario, SweepEngine};

/// The Fig. 6 scenario grid: the core-count and precision sweeps plus the
/// int8 series reused by the Fig. 6b DVFS sweep (one cache entry).
pub fn fig6_scenarios() -> Vec<Scenario> {
    let mut v = Vec::new();
    for cores in [2usize, 4] {
        v.push(Scenario::IntMatmul { w: IntWidth::I8, cores });
    }
    for cores in [1usize, 8] {
        for w in [IntWidth::I8, IntWidth::I16, IntWidth::I32] {
            v.push(Scenario::IntMatmul { w, cores });
        }
    }
    for w in [FpWidth::F32, FpWidth::F16x2] {
        v.push(Scenario::FpMatmul { w, cores: 8 });
    }
    // The Fig. 6b V/f series: same program as the 8-core int8 row above —
    // the memoization case the sweep cache exists for.
    v.push(Scenario::IntMatmul { w: IntWidth::I8, cores: 8 });
    v
}

/// Fig. 6: matmul performance and efficiency across data formats, FC
/// (1 core) vs cluster (8 cores), LV/HV, plus the HWCE point.
pub fn fig6(eng: &SweepEngine) -> String {
    let mut t = Table::new(
        "Fig. 6 - matmul performance & efficiency vs format",
        &["Config", "Format", "GOPS @HV", "GOPS/W @LV"],
    );
    // Core-count sweep for the int8 series (the Fig. 6 x-axis).
    for cores in [2usize, 4] {
        let kr = eng.kernel_run(Scenario::IntMatmul { w: IntWidth::I8, cores });
        let (gops, _) = coordinator::efficiency(&kr, power::HV, 0.0);
        let (_, eff) = coordinator::efficiency(&kr, power::LV, 0.0);
        t.row(&[
            format!("Cluster ({cores} cores)"),
            "int8".into(),
            f2(gops),
            format!("{eff:.0}"),
        ]);
    }
    for (label, cores) in [("FC (1 core)", 1usize), ("Cluster (8 cores)", 8)] {
        for w in [IntWidth::I8, IntWidth::I16, IntWidth::I32] {
            let kr = eng.kernel_run(Scenario::IntMatmul { w, cores });
            let (gops_hv, _) = coordinator::efficiency(&kr, power::HV, 0.0);
            let (_, eff_lv) = coordinator::efficiency(&kr, power::LV, 0.0);
            // FC shares: a single core burns roughly an eighth of the
            // cluster's switched capacitance.
            let (gops, eff) = if cores == 1 {
                (gops_hv, eff_lv * 2.2) // FC-domain point (200 GOPS/W int8 anchor)
            } else {
                (gops_hv, eff_lv)
            };
            t.row(&[
                label.into(),
                format!("int{}", w.bytes() * 8),
                f2(gops),
                format!("{eff:.0}"),
            ]);
        }
    }
    for w in [FpWidth::F32, FpWidth::F16x2] {
        let kr = eng.kernel_run(Scenario::FpMatmul { w, cores: 8 });
        let (gops, _) = coordinator::efficiency(&kr, power::HV, 0.0);
        let (_, eff) = coordinator::efficiency(&kr, power::LV, 0.0);
        t.row(&[
            "Cluster (8 cores)".into(),
            if w == FpWidth::F32 { "fp32".into() } else { "fp16 simd".into() },
            f2(gops),
            format!("{eff:.0}"),
        ]);
    }
    // HWCE point (conv workload).
    let job = crate::hwce::ConvJob {
        h: 16,
        w: 56,
        cin: 64,
        cout: 64,
        precision: crate::hwce::Precision::Int8,
        partials_in_l1: false,
    };
    let gops = job.mac_per_cycle() * 2.0 * power::HV.f_cl / 1e9;
    let p = power::cluster_power_w(power::LV, 0.12, 1.0) + power::soc_power_w(power::LV, 0.1);
    let eff = job.mac_per_cycle() * 2.0 * power::LV.f_cl / 1e9 / p;
    t.row(&["HWCE (8-bit conv)".into(), "int8".into(), f2(gops), format!("{eff:.0}")]);

    // Voltage/frequency sweep (the Fig. 6 x-axis): efficiency peaks at
    // low voltage, performance at high — the power/performance/precision
    // scalability story of the abstract. Cycle counts are frequency-
    // independent, so all four points derive from one cached simulation.
    let mut v = Table::new(
        "Fig. 6b - int8 matmul across the DVFS range (8 cores)",
        &["Vdd", "f_cl", "GOPS", "GOPS/W"],
    );
    let kr8 = eng.kernel_run(Scenario::IntMatmul { w: IntWidth::I8, cores: 8 });
    for (vdd, f) in pt::VF_ANCHORS {
        let op = power::tables::OperatingPoint { name: "sweep", vdd, f_soc: f, f_cl: f };
        let (gops, eff) = coordinator::efficiency(&kr8, op, 0.0);
        v.row(&[
            format!("{vdd:.1} V"),
            format!("{:.0} MHz", f / 1e6),
            f2(gops),
            format!("{eff:.0}"),
        ]);
    }
    format!(
        "{}\n{}\npaper anchors: cluster int8 15.6 GOPS / 614 GOPS/W; fp32 2 GFLOPS / 79 GFLOPS/W; fp16 3.3 / 129; HWCE 1.3 TOPS/W\n",
        t.render(),
        v.render()
    )
}

/// The §II-A duty-cycle trade-off: warm boot from retentive L2 vs zero-
/// retention MRAM restore — "depending on the duty cycle and wake-up
/// latency requirement of the target IoT application, one or the other
/// approach can be selected". Extra reproduction beyond the paper's
/// figures (the text makes the claim without a plot).
pub fn bootmodel() -> String {
    use crate::mem::{BulkChannel, Mram};
    use crate::power::PowerMode::*;
    let mram = Mram::new();
    let image: u64 = 256 * 1024;
    let active = SocActive { op: power::NOM, fc_util: 1.0 };
    let sleep_ret = RetentiveSleep { retentive_l2_bytes: image as usize };
    let restore_s = mram.transfer_cycles(image, power::NOM.f_soc, false) as f64
        / power::NOM.f_soc;
    let mut t = Table::new(
        "Warm-boot trade-off (256 kB image, 10 ms work per wake)",
        &["wakes/hour", "retentive-L2 avg", "MRAM-restore avg", "winner"],
    );
    for wph in [1.0f64, 10.0, 100.0, 1_000.0, 10_000.0, 40_000.0] {
        let period = 3600.0 / wph;
        let p_ret =
            power::Pmu::duty_cycled_power_w(active, sleep_ret, (10e-3_f64).min(period), period)
                .expect("active time clamped to the period");
        let p_mram = power::Pmu::duty_cycled_power_w(
            active,
            DeepSleep,
            (10e-3 + restore_s).min(period),
            period,
        )
        .expect("active time clamped to the period");
        t.row(&[
            format!("{wph:.0}"),
            si_power(p_ret),
            si_power(p_mram),
            if p_ret < p_mram { "retention" } else { "MRAM boot" }.into(),
        ]);
    }
    format!(
        "{}\nMRAM restore latency: {:.2} ms; crossover where restore energy/wake = standing retention power\n",
        t.render(),
        restore_s * 1e3
    )
}

/// Fig. 7: power modes.
pub fn fig7() -> String {
    use power::PowerMode::*;
    let modes: Vec<(&str, f64)> = vec![
        ("Deep sleep", DeepSleep.power_w()),
        ("Cognitive sleep (CWU @32kHz)", CognitiveSleep { retentive_l2_bytes: 0 }.power_w()),
        (
            "Cognitive + 16 kB retentive",
            CognitiveSleep { retentive_l2_bytes: 16 * 1024 }.power_w(),
        ),
        (
            "Cognitive + 128 kB retentive",
            CognitiveSleep { retentive_l2_bytes: 128 * 1024 }.power_w(),
        ),
        (
            "Cognitive + 1.6 MB retentive",
            CognitiveSleep { retentive_l2_bytes: 1600 * 1024 }.power_w(),
        ),
        ("SoC active (FC idle, LV)", SocActive { op: power::LV, fc_util: 0.1 }.power_w()),
        ("SoC active (FC busy, HV)", SocActive { op: power::HV, fc_util: 1.0 }.power_w()),
        (
            "Cluster active (8 cores, HV)",
            ClusterActive { op: power::HV, fc_util: 0.3, core_util: 1.0, hwce_active: 0.0 }
                .power_w(),
        ),
        (
            "Cluster + HWCE (HV)",
            ClusterActive { op: power::HV, fc_util: 0.3, core_util: 1.0, hwce_active: 1.0 }
                .power_w(),
        ),
    ];
    let mut t = Table::new("Fig. 7 - power modes", &["Mode", "Power"]);
    for (name, p) in modes {
        t.row(&[name.into(), si_power(p)]);
    }
    format!(
        "{}\npaper anchors: 1.7 uW cognitive sleep; 2.8-123.7 uW retentive; 0.7-15 mW SoC; <=49.4 mW cluster\n",
        t.render()
    )
}

/// The Fig. 8 scenario grid: every NSAA kernel at both FP widths (the LV
/// and HV columns derive from the same cached cycle counts).
pub fn fig8_scenarios() -> Vec<Scenario> {
    NSAA_KERNELS
        .iter()
        .flat_map(|&name| {
            [FpWidth::F32, FpWidth::F16x2].map(|w| Scenario::Nsaa { name, w })
        })
        .collect()
}

/// Fig. 8: FP NSAA performance and efficiency, FP32 vs FP16, LV/HV.
pub fn fig8(eng: &SweepEngine) -> String {
    let mut t = Table::new(
        "Fig. 8 - FP NSAA kernels (8 cores)",
        &[
            "Kernel", "fmt", "MOPS @LV", "MOPS @HV", "MOPS/mW @LV", "FP int. %", "f16 speedup",
        ],
    );
    let mut speedup_sum = 0.0;
    for name in NSAA_KERNELS {
        let k32 = eng.kernel_run(Scenario::Nsaa { name, w: FpWidth::F32 });
        let k16 = eng.kernel_run(Scenario::Nsaa { name, w: FpWidth::F16x2 });
        let speedup = k32.stats.cycles as f64 / k16.stats.cycles as f64
            * (k16.ops as f64 / k32.ops as f64);
        speedup_sum += speedup;
        for (kr, fmt) in [(&k32, "fp32"), (&k16, "fp16")] {
            let mops_lv = kr.gops_at(pt::LV.f_cl) * 1e3;
            let mops_hv = kr.gops_at(pt::HV.f_cl) * 1e3;
            let (_, eff) = coordinator::efficiency(kr, power::LV, 0.0);
            t.row(&[
                name.into(),
                fmt.into(),
                format!("{mops_lv:.0}"),
                format!("{mops_hv:.0}"),
                f2(eff),
                f1(kr.fp_intensity() * 100.0),
                if fmt == "fp16" { f2(speedup) } else { "-".into() },
            ]);
        }
    }
    format!(
        "{}\naverage f16 speedup: {:.2}x (paper: 1.46x average)\n",
        t.render(),
        speedup_sum / NSAA_KERNELS.len() as f64
    )
}

/// Fig. 9: the tiling pipeline schedule (text Gantt over one layer).
pub fn fig9(eng: &SweepEngine) -> String {
    let net = mobilenet_v2();
    let rep = eng.network_report(&net, PipelineConfig::nominal_sw(StorePolicy::AllMram));
    // Render 4 pipeline stages over 3 tiles of a representative layer.
    let l = &rep.layers[4];
    let tile_c = l.compute_cycles.max(1) / 3;
    let tile_d = l.l2l1_cycles.max(1) / 6; // in + out per tile
    let mut out = format!(
        "== Fig. 9 - double-buffered pipeline ({} @ {}; cycles/tile) ==\n",
        l.name, rep.network
    );
    let bar = |n: u64| "#".repeat(((n / 2500) as usize).clamp(1, 60));
    out.push_str(&format!("L3->L2 weights : {} ({} cyc total, overlapped with prev layer)\n", bar(l.l3_cycles.max(1)), l.l3_cycles));
    for tile in 0..3 {
        let pad = "  ".repeat(tile);
        out.push_str(&format!("tile{tile} L2->L1   : {pad}{}\n", bar(tile_d)));
        out.push_str(&format!("tile{tile} compute  : {pad}  {}\n", bar(tile_c)));
        out.push_str(&format!("tile{tile} L1->L2   : {pad}    {}\n", bar(tile_d)));
    }
    out.push_str("stages overlap: layer latency = max(stage totals) + fill\n");
    out
}

/// Fig. 10: MobileNetV2 layer-wise latency breakdown.
pub fn fig10(eng: &SweepEngine) -> String {
    let net = mobilenet_v2();
    let mram = eng.network_report(&net, PipelineConfig::nominal_sw(StorePolicy::AllMram));
    let hyper = eng.network_report(&net, PipelineConfig::nominal_sw(StorePolicy::AllHyperRam));
    let mut t = Table::new(
        "Fig. 10 - MobileNetV2 layer-wise latency @250 MHz [us]",
        &["Layer", "compute", "L2<->L1", "L3->L2 (MRAM)", "bound"],
    );
    let us = |c: u64| f1(c as f64 / 250e6 * 1e6);
    for l in &mram.layers {
        t.row(&[
            l.name.clone(),
            us(l.compute_cycles),
            us(l.l2l1_cycles),
            us(l.l3_cycles),
            format!("{:?}", l.bound),
        ]);
    }
    let compute_bound = mram
        .layers
        .iter()
        .take(mram.layers.len() - 1)
        .filter(|l| l.bound == Bound::Compute)
        .count();
    format!(
        "{}\ntotal: MRAM {:.1} ms / HyperRAM {:.1} ms (paper: ~3 ms apart, all but final layer compute-bound: {}/{} here)\n",
        t.render(),
        mram.latency_s() * 1e3,
        hyper.latency_s() * 1e3,
        compute_bound,
        mram.layers.len() - 1,
    )
}

/// Fig. 11: MobileNetV2 inference energy, MRAM vs HyperRAM weights.
pub fn fig11(eng: &SweepEngine) -> String {
    let net = mobilenet_v2();
    let m = eng.network_report(&net, PipelineConfig::nominal_sw(StorePolicy::AllMram));
    let h = eng.network_report(&net, PipelineConfig::nominal_sw(StorePolicy::AllHyperRam));
    let mut t = Table::new(
        "Fig. 11 - MobileNetV2 energy per inference [mJ]",
        &["Flow", "compute", "L2<->L1", "L1", "L3 weights", "total", "latency ms", "fps"],
    );
    for (name, r) in [("MRAM (on-chip)", &m), ("HyperRAM (legacy)", &h)] {
        let e = &r.energy;
        t.row(&[
            name.into(),
            f2(e.compute_pj / 1e9),
            f2(e.l2l1_pj / 1e9),
            f2(e.l1_pj / 1e9),
            f2((e.mram_pj + e.hyperram_pj) / 1e9),
            f2(r.energy_mj()),
            f1(r.latency_s() * 1e3),
            f1(r.fps()),
        ]);
    }
    format!(
        "{}\npaper: 4.16 mJ -> 1.19 mJ (3.5x); measured ratio: {:.2}x\n",
        t.render(),
        h.energy_mj() / m.energy_mj()
    )
}
