//! External/non-volatile memory channel models (§II-A, Table VI).
//!
//! Both channels are *functional* (they hold real bytes — DNN weights live
//! here during inference, exactly as on the silicon) and *timed*
//! (bandwidth anchored to the paper's measured sustained rates). Energy is
//! charged per byte by the power ledger using the Table VI coefficients
//! (with the erratum correction documented in DESIGN.md §4: MRAM
//! 20 pJ/B, HyperRAM 880 pJ/B — "MRAM provides over 40× better energy
//! efficiency").

pub mod ecc;
pub mod hyperram;
pub mod mram;

pub use hyperram::HyperRam;
pub use mram::{MemFault, Mram};

use crate::common::Cycles;

/// A bulk-transfer channel into L2 (driven by the I/O DMA).
pub trait BulkChannel {
    /// Sustained read bandwidth in bytes per second.
    fn read_bandwidth(&self) -> f64;
    /// Sustained write bandwidth in bytes per second.
    fn write_bandwidth(&self) -> f64;
    /// Fixed per-transfer setup latency in SoC cycles (DMA programming +
    /// protocol command phase).
    fn setup_cycles(&self) -> Cycles;
    /// Access energy per byte moved (pJ/B, Table VI).
    fn energy_pj_per_byte(&self) -> f64;

    /// Cycles for a transfer of `bytes` at SoC frequency `f_soc` Hz.
    fn transfer_cycles(&self, bytes: u64, f_soc: f64, write: bool) -> Cycles {
        let bw = if write { self.write_bandwidth() } else { self.read_bandwidth() };
        let seconds = bytes as f64 / bw;
        self.setup_cycles() + (seconds * f_soc).ceil() as Cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mram_is_40x_more_efficient_than_hyperram() {
        let m = Mram::new();
        let h = HyperRam::new(8 * 1024 * 1024);
        let ratio = h.energy_pj_per_byte() / m.energy_pj_per_byte();
        assert!(ratio > 40.0, "ratio = {ratio}"); // "over 40x better"
    }

    #[test]
    fn table6_bandwidth_anchors() {
        // MRAM <-> L2: 300 MB/s; HyperRAM <-> L2: 200 MB/s (Table VI,
        // erratum-corrected: the extracted rows are swapped — see DESIGN.md §4),
        // measured on a large transfer at the 250 MHz nominal point.
        let f = 250e6;
        let bytes = 1 << 20;
        let m = Mram::new();
        let h = HyperRam::new(8 * 1024 * 1024);
        let mbps = |cyc: Cycles| bytes as f64 / (cyc as f64 / f) / 1e6;
        let m_bw = mbps(m.transfer_cycles(bytes, f, false));
        let h_bw = mbps(h.transfer_cycles(bytes, f, false));
        assert!((m_bw - 300.0).abs() < 15.0, "MRAM bw = {m_bw} MB/s");
        assert!((h_bw - 200.0).abs() < 10.0, "HyperRAM bw = {h_bw} MB/s");
    }
}
