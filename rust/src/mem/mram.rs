//! The 4 MB embedded MRAM (§II-A).
//!
//! A dedicated controller converts the MRAM macro protocol: 78-bit reads
//! (64 data + 14 ECC) at up to 40 MHz, i.e. 40e6 × 64 bits = 2.5 Gbit/s ≈
//! 312 MB/s raw; sustained through the I/O DMA into L2 the paper measures
//! 300 MB/s (Table VI, erratum-corrected rows; consistent with the raw
//! 2.5 Gbit/s = 312 MB/s interface). Reads cost 20 pJ/B (Table VI, erratum-corrected).
//! MRAM writes are much slower and more expensive — the paper uses the
//! array for read-mostly data (weights, boot code); we model writes at
//! 1/8 the read bandwidth with 10× the energy (typical for STT-MRAM
//! write pulses; documented assumption, DESIGN.md §5).
//!
//! The store is functional: weights written at deploy time are the bytes
//! DNN inference later streams out. ECC is real ([`super::ecc`]): a
//! bit-flip injection API exercises the correction path (HDC's claimed
//! error resilience, and MRAM's raison d'être as sleep storage, both rest
//! on it).

use crate::common::Cycles;

use super::ecc::{self, EccResult};
use super::BulkChannel;

/// MRAM capacity: 4 MB.
pub const MRAM_SIZE: usize = 4 * 1024 * 1024;

/// Sustained read bandwidth into L2 via I/O DMA (Table VI).
pub const READ_BW: f64 = 300.0e6;
/// Modelled write bandwidth (assumption, see module docs).
pub const WRITE_BW: f64 = 25.0e6;
/// Read energy (Table VI, erratum-corrected).
pub const READ_PJ_PER_BYTE: f64 = 20.0;
/// Write energy (assumption: 10× read).
pub const WRITE_PJ_PER_BYTE: f64 = 200.0;

/// Counters for ECC events.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EccStats {
    pub corrected: u64,
    pub detected: u64,
}

/// A typed fault raised by a memory tier (ISSUE 6): the controller's
/// uncorrectable-error interrupt, surfaced to callers so a poisoned read
/// is distinguishable from a clean one instead of silently handing back
/// the corrupt word.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MemFault {
    /// SECDED reported detected-uncorrectable (an even ≥2-flip pattern)
    /// in at least one word of the request. `data` carries the
    /// best-effort bytes (what the controller drives onto the bus while
    /// raising the interrupt); `word_offsets` lists the byte offset of
    /// each poisoned 64-bit word, relative to the start of the read.
    Uncorrectable { data: Vec<u8>, word_offsets: Vec<usize> },
}

impl MemFault {
    /// The best-effort payload, fault notwithstanding.
    pub fn into_data(self) -> Vec<u8> {
        match self {
            MemFault::Uncorrectable { data, .. } => data,
        }
    }
}

/// The MRAM array + controller.
pub struct Mram {
    /// Stored as ECC codewords per 64-bit word (16 bytes each for
    /// simplicity; the physical macro packs 78 bits).
    words: Vec<u128>,
    pub ecc_stats: EccStats,
    pub bytes_read: u64,
    pub bytes_written: u64,
}

impl Mram {
    pub fn new() -> Self {
        Self {
            words: vec![ecc::encode(0); MRAM_SIZE / 8],
            ecc_stats: EccStats::default(),
            bytes_read: 0,
            bytes_written: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        MRAM_SIZE
    }

    /// Write `bytes` at `offset` (deploy-time weight loading, warm-boot
    /// image store). Byte-granular via read-modify-write of 64-bit words.
    pub fn write(&mut self, offset: usize, bytes: &[u8]) {
        assert!(offset + bytes.len() <= MRAM_SIZE, "MRAM write out of range");
        for (i, &b) in bytes.iter().enumerate() {
            let addr = offset + i;
            let (w, sh) = (addr / 8, (addr % 8) * 8);
            let mut val = ecc::decode(self.words[w]).value();
            val = (val & !(0xFFu64 << sh)) | ((b as u64) << sh);
            self.words[w] = ecc::encode(val);
        }
        self.bytes_written += bytes.len() as u64;
    }

    /// Read `len` bytes at `offset`, passing every word through ECC
    /// decode (correcting injected single-bit upsets). Each 64-bit word
    /// is decoded once, as the controller does (§Perf: the earlier
    /// byte-granular path decoded every word up to eight times).
    ///
    /// Returns `Err(MemFault::Uncorrectable)` if any word decoded as
    /// detected-uncorrectable (ISSUE 6 satellite: previously the corrupt
    /// word was handed back with only a counter bump). The error still
    /// carries the full best-effort byte image plus the offsets of the
    /// poisoned words, so fault campaigns can measure propagation.
    pub fn read(&mut self, offset: usize, len: usize) -> Result<Vec<u8>, MemFault> {
        assert!(offset + len <= MRAM_SIZE, "MRAM read out of range");
        let mut out = Vec::with_capacity(len);
        let mut poisoned: Vec<usize> = Vec::new();
        let mut addr = offset;
        while addr < offset + len {
            let (w, sh) = (addr / 8, addr % 8);
            let val = match ecc::decode(self.words[w]) {
                EccResult::Clean(v) => v,
                EccResult::Corrected(v) => {
                    self.ecc_stats.corrected += 1;
                    // Scrub: rewrite the corrected codeword.
                    self.words[w] = ecc::encode(v);
                    v
                }
                EccResult::Detected(v) => {
                    self.ecc_stats.detected += 1;
                    poisoned.push((w * 8).saturating_sub(offset));
                    v
                }
            };
            let take = (8 - sh).min(offset + len - addr);
            out.extend_from_slice(&val.to_le_bytes()[sh..sh + take]);
            addr += take;
        }
        self.bytes_read += len as u64;
        if poisoned.is_empty() {
            Ok(out)
        } else {
            Err(MemFault::Uncorrectable { data: out, word_offsets: poisoned })
        }
    }

    /// Inject a bit flip into the codeword holding byte `offset`
    /// (`bit` < 73): radiation/retention upset model.
    pub fn inject_bit_flip(&mut self, offset: usize, bit: u32) {
        let w = offset / 8;
        self.words[w] ^= 1u128 << (bit % 72);
    }

    /// Raw codeword holding byte `offset`, without decoding, counting or
    /// scrubbing — the fault-campaign classifier peeks at staged upsets
    /// before the architectural read consumes them.
    pub fn codeword(&self, offset: usize) -> u128 {
        self.words[offset / 8]
    }

    /// Non-volatile: state survives power-off (modelled as a no-op — the
    /// store persists; this method documents the contract and is used by
    /// the PMU tests).
    pub fn power_cycle(&mut self) {}
}

impl Default for Mram {
    fn default() -> Self {
        Self::new()
    }
}

impl BulkChannel for Mram {
    fn read_bandwidth(&self) -> f64 {
        READ_BW
    }

    fn write_bandwidth(&self) -> f64 {
        WRITE_BW
    }

    fn setup_cycles(&self) -> Cycles {
        // DMA channel programming + MRAM command phase at 40 MHz.
        64
    }

    fn energy_pj_per_byte(&self) -> f64 {
        READ_PJ_PER_BYTE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rw_roundtrip_unaligned() {
        let mut m = Mram::new();
        let data: Vec<u8> = (0..=255).collect();
        m.write(13, &data);
        assert_eq!(m.read(13, 256).unwrap(), data);
        assert_eq!(m.ecc_stats, EccStats::default());
    }

    #[test]
    fn single_upset_corrected_and_scrubbed() {
        let mut m = Mram::new();
        m.write(0, &[0xAB; 8]);
        m.inject_bit_flip(0, 17);
        assert_eq!(m.read(0, 8).unwrap(), vec![0xAB; 8]);
        assert!(m.ecc_stats.corrected >= 1);
        // Scrubbed: a second read is clean.
        let before = m.ecc_stats.corrected;
        assert_eq!(m.read(0, 8).unwrap(), vec![0xAB; 8]);
        assert_eq!(m.ecc_stats.corrected, before);
    }

    #[test]
    fn double_upset_detected() {
        let mut m = Mram::new();
        m.write(0, &[0x55; 8]);
        m.inject_bit_flip(0, 3);
        m.inject_bit_flip(0, 40);
        let MemFault::Uncorrectable { data, word_offsets } = m.read(0, 8).unwrap_err();
        assert_eq!(word_offsets, vec![0], "the poisoned word is reported");
        assert_eq!(data.len(), 8, "best-effort bytes still delivered");
        assert!(m.ecc_stats.detected >= 1);
    }

    /// A poisoned read names only the faulty words; neighbours come back
    /// intact inside the best-effort image.
    #[test]
    fn poisoned_read_reports_only_faulty_words() {
        let mut m = Mram::new();
        m.write(0, &[0x11; 24]);
        m.inject_bit_flip(8, 3); // word 1 gets a double flip
        m.inject_bit_flip(8, 40);
        let MemFault::Uncorrectable { data, word_offsets } = m.read(0, 24).unwrap_err();
        assert_eq!(word_offsets, vec![8]);
        assert_eq!(&data[0..8], &[0x11; 8]);
        assert_eq!(&data[16..24], &[0x11; 8]);
    }

    #[test]
    fn state_survives_power_cycle() {
        let mut m = Mram::new();
        m.write(1000, b"warm boot image");
        m.power_cycle();
        assert_eq!(m.read(1000, 15).unwrap(), b"warm boot image");
    }

    #[test]
    fn write_slower_than_read() {
        let m = Mram::new();
        let rd = m.transfer_cycles(4096, 250e6, false);
        let wr = m.transfer_cycles(4096, 250e6, true);
        assert!(wr > 4 * rd);
    }
}
