//! External HyperRAM over the HyperBus/OCTA-SPI DDR interface (§II-A).
//!
//! The interface peaks at 1.6 Gbit/s (= 200 MB/s per direction DDR at
//! 100 MHz × 8 bits... the paper quotes the aggregate link); measured
//! sustained into L2 is 300 MB/s (Table VI) with 880 pJ/B access energy
//! (erratum-corrected; off-chip I/O dominates — this is the number that
//! makes on-chip MRAM 40× better and drives Fig. 11's 3.5× system-energy
//! win). Burst transfers pay a CS-assert + command/address phase per burst
//! (the "legacy flow" the paper compares against).

use crate::common::Cycles;

use super::BulkChannel;

/// Sustained bandwidth into L2 (Table VI).
pub const BW: f64 = 200.0e6;
/// Access energy, off-chip (Table VI, erratum-corrected).
pub const PJ_PER_BYTE: f64 = 880.0;

/// An external HyperRAM module of configurable size.
pub struct HyperRam {
    data: Vec<u8>,
    pub bytes_read: u64,
    pub bytes_written: u64,
}

impl HyperRam {
    pub fn new(size: usize) -> Self {
        Self { data: vec![0; size], bytes_read: 0, bytes_written: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.data.len()
    }

    pub fn write(&mut self, offset: usize, bytes: &[u8]) {
        assert!(offset + bytes.len() <= self.data.len(), "HyperRAM write OOR");
        self.data[offset..offset + bytes.len()].copy_from_slice(bytes);
        self.bytes_written += bytes.len() as u64;
    }

    pub fn read(&mut self, offset: usize, len: usize) -> Vec<u8> {
        assert!(offset + len <= self.data.len(), "HyperRAM read OOR");
        self.bytes_read += len as u64;
        self.data[offset..offset + len].to_vec()
    }

    /// Volatile: contents are lost on power-off (unlike MRAM) — the
    /// functional difference behind the warm-boot trade-off of §II-A.
    pub fn power_cycle(&mut self) {
        self.data.fill(0);
    }
}

impl BulkChannel for HyperRam {
    fn read_bandwidth(&self) -> f64 {
        BW
    }

    fn write_bandwidth(&self) -> f64 {
        BW
    }

    fn setup_cycles(&self) -> Cycles {
        // CS assert + 6-byte command/address + initial latency beats.
        48
    }

    fn energy_pj_per_byte(&self) -> f64 {
        PJ_PER_BYTE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rw_roundtrip() {
        let mut h = HyperRam::new(1024);
        h.write(100, &[1, 2, 3]);
        assert_eq!(h.read(100, 3), vec![1, 2, 3]);
        assert_eq!(h.bytes_read, 3);
        assert_eq!(h.bytes_written, 3);
    }

    #[test]
    fn volatile_on_power_cycle() {
        let mut h = HyperRam::new(64);
        h.write(0, &[0xFF; 8]);
        h.power_cycle();
        assert_eq!(h.read(0, 8), vec![0; 8]);
    }
}
