//! SECDED ECC for the MRAM's 78-bit interface (64 data + 14 check bits).
//!
//! The controller "completely abstract[s] to the end-user the complexity
//! of the specific protocol" (§II-A); part of that protocol is per-word
//! ECC. We implement an extended Hamming SECDED(72,64) — 8 of the 14
//! available check bits; the macro's remaining bits cover the MRAM-internal
//! redundancy, which we fold into the same correction guarantee. Single
//! bit-flips are corrected transparently, double flips are detected and
//! reported (the controller would raise an interrupt).

/// Result of decoding one 64-bit word.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EccResult {
    Clean(u64),
    Corrected(u64),
    /// Uncorrectable (≥2 flips): data returned best-effort.
    Detected(u64),
}

impl EccResult {
    #[inline]
    pub fn value(self) -> u64 {
        match self {
            EccResult::Clean(v) | EccResult::Corrected(v) | EccResult::Detected(v) => v,
        }
    }
}

/// Number of Hamming check bits for 64 data bits (positions 1..72, powers
/// of two), plus one overall parity bit.
const CHECK_BITS: usize = 7;

/// Precomputed parity masks: `MASKS[c]` covers every codeword position
/// whose index has bit `c` set, so syndrome bit c = popcount(cw & MASKS[c])
/// & 1 — turns per-word ECC from ~500 bit probes into 7 popcounts. Built
/// at compile time, so the per-word hot path carries no lazy-init check
/// (§Perf: `encode`/`decode` run once per 64-bit MRAM word).
const MASKS: [u128; CHECK_BITS] = parity_masks();

const fn parity_masks() -> [u128; CHECK_BITS] {
    let mut masks = [0u128; CHECK_BITS];
    let mut c = 0;
    while c < CHECK_BITS {
        let mut pos = 1u32;
        while pos <= 71 {
            if pos & (1u32 << c) != 0 {
                masks[c] |= 1u128 << pos;
            }
            pos += 1;
        }
        c += 1;
    }
    masks
}

/// Data-bit codeword positions (the non-power-of-two slots in 1..=71).
const DATA_POS: [u32; 64] = data_positions();

const fn data_positions() -> [u32; 64] {
    let mut out = [0u32; 64];
    let mut d = 0;
    let mut pos = 1u32;
    while pos <= 71 {
        if !pos.is_power_of_two() {
            out[d] = pos;
            d += 1;
        }
        pos += 1;
    }
    out
}

// 64 data slots exactly fill positions 1..=71 minus the 7 check bits.
const _: () = assert!(DATA_POS[63] == 71);

/// Expand 64 data bits into a 72-bit codeword layout: positions 1..=71,
/// with powers-of-two positions reserved for check bits and position 0 for
/// the overall parity.
#[inline]
fn encode_codeword(data: u64) -> u128 {
    let mut cw: u128 = 0;
    let mut d = 0;
    while d < 64 {
        cw |= (((data >> d) & 1) as u128) << DATA_POS[d];
        d += 1;
    }
    // Hamming check bits via the precomputed masks.
    let mut c = 0;
    while c < CHECK_BITS {
        if (cw & MASKS[c]).count_ones() & 1 == 1 {
            cw |= 1u128 << (1u32 << c);
        }
        c += 1;
    }
    // Overall parity at position 0 (extends Hamming to SECDED).
    cw |= (cw.count_ones() & 1) as u128;
    cw
}

/// Extract the 64 data bits from a codeword.
#[inline]
fn extract_data(cw: u128) -> u64 {
    let mut data = 0u64;
    let mut d = 0;
    while d < 64 {
        data |= (((cw >> DATA_POS[d]) & 1) as u64) << d;
        d += 1;
    }
    data
}

/// Encode one 64-bit word to its 73-bit (data+check+parity) codeword.
#[inline]
pub fn encode(data: u64) -> u128 {
    encode_codeword(data)
}

/// Decode a codeword, correcting single-bit and detecting double-bit
/// errors.
#[inline]
pub fn decode(cw: u128) -> EccResult {
    let mut syndrome = 0u32;
    for (c, &mask) in MASKS.iter().enumerate() {
        syndrome |= ((cw & mask).count_ones() & 1) << c;
    }
    let overall = cw.count_ones() % 2;

    if syndrome == 0 && overall == 0 {
        return EccResult::Clean(extract_data(cw));
    }
    if overall == 1 {
        // Odd number of flips: assume 1, correct it.
        let fixed = if syndrome == 0 {
            cw ^ 1 // the parity bit itself flipped
        } else {
            cw ^ (1u128 << syndrome)
        };
        return EccResult::Corrected(extract_data(fixed));
    }
    // Even flips with nonzero syndrome: uncorrectable.
    EccResult::Detected(extract_data(cw))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;

    #[test]
    fn clean_roundtrip() {
        for v in [0u64, u64::MAX, 0xDEAD_BEEF_CAFE_F00D, 1, 1 << 63] {
            assert_eq!(decode(encode(v)), EccResult::Clean(v));
        }
    }

    /// ISSUE 6 satellite: the full single-bit sweep, replacing the former
    /// 200-case random property. The silicon interface is 78-bit (64 data
    /// + 14 check); the model folds the macro-internal redundancy into
    /// SECDED(72,64) (see the module docs), so positions 0..=71 — parity
    /// bit, check bits and data bits alike — are the entire modeled
    /// codeword, and every one of them is swept here.
    #[test]
    fn every_single_bit_position_corrects_exhaustively() {
        let mut rng = Rng::new(0xECC1);
        let mut values = vec![0u64, u64::MAX, 0xDEAD_BEEF_CAFE_F00D, 0x5555_5555_5555_5555];
        values.extend((0..4).map(|_| rng.next_u64()));
        for v in values {
            let cw = encode(v);
            for pos in 0..72u32 {
                match decode(cw ^ (1u128 << pos)) {
                    EccResult::Corrected(got) => assert_eq!(got, v, "flip at {pos}"),
                    other => panic!("flip at {pos}: expected correction, got {other:?}"),
                }
            }
        }
    }

    /// ISSUE 6 satellite: all C(72,2) = 2556 double-bit patterns report
    /// `Detected` — a stratified sweep that is simply exhaustive.
    #[test]
    fn every_double_bit_pair_detected_exhaustively() {
        for v in [0u64, u64::MAX, 0xDEAD_BEEF_CAFE_F00D] {
            let cw = encode(v);
            for p1 in 0..72u32 {
                for p2 in (p1 + 1)..72 {
                    match decode(cw ^ (1u128 << p1) ^ (1u128 << p2)) {
                        EccResult::Detected(_) => {}
                        other => panic!("flips at {p1},{p2}: expected detection, got {other:?}"),
                    }
                }
            }
        }
    }

    /// Triple flips exceed SECDED's guarantee: overall parity is odd
    /// again, so the decoder always takes the single-flip branch and
    /// "corrects" — to the right data when all three flips landed in
    /// check/parity positions, to wrong data otherwise. This exhaustive
    /// characterization (all C(72,3) = 59640 triples) pins the escape
    /// surface the fault campaigns classify as silent data corruption.
    #[test]
    fn triple_flips_escape_as_miscorrections_never_detected() {
        let v = 0xA5A5_5A5A_F00D_BEEF_u64;
        let cw = encode(v);
        let (mut silent, mut lucky) = (0u64, 0u64);
        for p1 in 0..72u32 {
            for p2 in (p1 + 1)..72 {
                for p3 in (p2 + 1)..72 {
                    match decode(cw ^ (1u128 << p1) ^ (1u128 << p2) ^ (1u128 << p3)) {
                        EccResult::Corrected(got) if got == v => lucky += 1,
                        EccResult::Corrected(_) => silent += 1,
                        other => panic!("flips {p1},{p2},{p3}: got {other:?}"),
                    }
                }
            }
        }
        assert!(silent > 0, "triple flips must expose an SDC escape surface");
        assert!(lucky > 0, "check-bit-only triples leave the data intact");
        assert_eq!(silent + lucky, 59_640);
    }
}
