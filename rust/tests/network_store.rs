//! Persistent network-report store invariants (ISSUE 4 acceptance):
//!
//! * a `NetworkReport` computed by one engine is served from disk to a
//!   later engine on the same directory (each engine stands in for a
//!   process: to the store it is exactly that — a cold in-memory memo
//!   over a shared directory);
//! * a second run of the fig9 / table7 reproductions serves **every**
//!   network report from disk, byte-identically (the acceptance
//!   criterion `scripts/ci.sh` re-checks end-to-end via `vega repro
//!   fig9 --stats`);
//! * corrupted or cross-tier entries are misses that fall back to
//!   recomputation — never wrong data, never a panic;
//! * the kernel tier and the network tier count independently.

use std::fs;
use std::path::PathBuf;

use vega::bench;
use vega::dnn::{mobilenet_v2, net_key, PipelineConfig, StorePolicy};
use vega::sweep::{DiskStore, SweepEngine};

/// Fresh per-test store directory (unique per process and case; removed
/// at entry so a leftover from a crashed run can't pollute counters).
fn store_dir(case: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("vega-net-store-test-{}-{case}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

fn engine_at(dir: &PathBuf, jobs: usize) -> SweepEngine {
    SweepEngine::with_disk(jobs, DiskStore::at(dir).expect("store dir"))
}

/// The single `.net` entry file of a store directory.
fn only_net_entry(dir: &PathBuf) -> PathBuf {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "net"))
        .collect();
    assert_eq!(entries.len(), 1, "expected exactly one network entry in {dir:?}");
    entries.pop().unwrap()
}

#[test]
fn network_reports_round_trip_across_engines() {
    let dir = store_dir("roundtrip");
    let net = mobilenet_v2();
    let cfg = PipelineConfig::nominal_sw(StorePolicy::GreedyMram);

    let cold = engine_at(&dir, 1);
    let first = cold.network_report(&net, cfg);
    assert_eq!(cold.network_counters(), (0, 1), "cold: one memo miss");
    assert_eq!(cold.disk_net_counters(), Some((0, 1, 1)), "cold: disk miss + write");
    assert_eq!(cold.disk_counters(), Some((0, 0, 0)), "kernel tier untouched");

    let warm = engine_at(&dir, 1);
    let second = warm.network_report(&net, cfg);
    assert_eq!(warm.disk_net_counters(), Some((1, 0, 0)), "warm: served from disk");
    assert_eq!(second.network, first.network);
    assert_eq!(second.total_cycles(), first.total_cycles());
    assert_eq!(second.energy_mj().to_bits(), first.energy_mj().to_bits());
    assert_eq!(second.mram_up_to, first.mram_up_to);
    assert_eq!(second.layers.len(), first.layers.len());
    for (a, b) in second.layers.iter().zip(&first.layers) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.latency_cycles, b.latency_cycles);
        assert_eq!(a.bound, b.bound);
        assert_eq!(a.store, b.store);
    }

    // A second lookup on the warm engine is a pure memo hit: the disk is
    // probed once per in-memory miss, never per lookup.
    warm.network_report(&net, cfg);
    assert_eq!(warm.network_counters(), (1, 1));
    assert_eq!(warm.disk_net_counters(), Some((1, 0, 0)));

    let _ = fs::remove_dir_all(&dir);
}

/// The acceptance repros: a second engine (process stand-in) renders
/// fig9 and table7 byte-identically with every network report served
/// from the on-disk store.
#[test]
fn fig9_and_table7_warm_start_entirely_from_disk() {
    let dir = store_dir("acceptance");

    let cold = engine_at(&dir, 2);
    let fig9_cold = bench::run_with("fig9", &cold).unwrap();
    let table7_cold = bench::run_with("table7", &cold).unwrap();
    let (_, net_runs) = cold.network_counters();
    let (dh, dm, dw) = cold.disk_net_counters().unwrap();
    assert_eq!(net_runs, 7, "fig9 = 1 MobileNetV2 run, table7 = 3 RepVGGs x SW+HWCE");
    assert_eq!((dh, dm, dw), (0, net_runs, net_runs), "cold run persists every report");

    let warm = engine_at(&dir, 2);
    let fig9_warm = bench::run_with("fig9", &warm).unwrap();
    let table7_warm = bench::run_with("table7", &warm).unwrap();
    assert_eq!(fig9_warm, fig9_cold, "fig9 must render byte-identically from disk");
    assert_eq!(table7_warm, table7_cold, "table7 must render byte-identically from disk");
    assert_eq!(
        warm.disk_net_counters(),
        Some((net_runs, 0, 0)),
        "second run serves every NetworkReport from disk"
    );

    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_network_entries_fall_back_to_recomputation() {
    let dir = store_dir("corrupt");
    let net = mobilenet_v2();
    let cfg = PipelineConfig::nominal_sw(StorePolicy::AllMram);
    let baseline = engine_at(&dir, 1).network_report(&net, cfg);

    let path = only_net_entry(&dir);
    let good = fs::read(&path).unwrap();

    // Truncation.
    fs::write(&path, &good[..good.len() / 2]).unwrap();
    let eng = engine_at(&dir, 1);
    let recovered = eng.network_report(&net, cfg);
    assert_eq!(eng.disk_net_counters(), Some((0, 1, 1)), "truncated entry is a miss");
    assert_eq!(recovered.total_cycles(), baseline.total_cycles());

    // Payload bit flip (checksum catches it).
    let mut flipped = fs::read(&path).unwrap();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x01;
    fs::write(&path, &flipped).unwrap();
    let eng = engine_at(&dir, 1);
    eng.network_report(&net, cfg);
    assert_eq!(eng.disk_net_counters(), Some((0, 1, 1)), "bit flip is a miss");

    // Garbage.
    fs::write(&path, b"not a network entry").unwrap();
    let eng = engine_at(&dir, 1);
    eng.network_report(&net, cfg);
    assert_eq!(eng.disk_net_counters(), Some((0, 1, 1)), "garbage is a miss");

    // The healed entry is valid again.
    let healed = engine_at(&dir, 1);
    healed.network_report(&net, cfg);
    assert_eq!(healed.disk_net_counters(), Some((1, 0, 0)));

    let _ = fs::remove_dir_all(&dir);
}

/// Distinct configs get distinct entries; the memo key is the canonical
/// `net_key` string, so the on-disk population matches the distinct-key
/// count exactly.
#[test]
fn one_entry_per_distinct_config() {
    let dir = store_dir("distinct");
    let net = mobilenet_v2();
    let configs = [
        PipelineConfig::nominal_sw(StorePolicy::AllMram),
        PipelineConfig::nominal_sw(StorePolicy::AllHyperRam),
        PipelineConfig::nominal_hwce(StorePolicy::AllMram),
    ];
    let keys: std::collections::HashSet<String> =
        configs.iter().map(|c| net_key(&net, c)).collect();
    assert_eq!(keys.len(), configs.len(), "configs must key distinctly");

    let eng = engine_at(&dir, 1);
    for c in &configs {
        eng.network_report(&net, *c);
    }
    let n_entries = fs::read_dir(&dir)
        .unwrap()
        .filter(|e| {
            e.as_ref().unwrap().path().extension().is_some_and(|x| x == "net")
        })
        .count();
    assert_eq!(n_entries, configs.len());
    assert_eq!(eng.disk_net_counters(), Some((0, 3, 3)));

    let _ = fs::remove_dir_all(&dir);
}
