//! Crash-safe resume acceptance (ISSUE 7), driven through the real
//! binary (`CARGO_BIN_EXE_vega`) the way an operator would drive it:
//!
//! * a `SIGKILL`ed mid-grid sweep resumes with `--resume` to output
//!   **byte-identical** to an uninterrupted run, with exact disk-store
//!   and journal counters for the work completed before the kill;
//! * a torn journal tail (the expected state after a kill mid-append)
//!   reads as "cell not done" and costs exactly one recomputation;
//!   trailing garbage after valid records costs nothing;
//! * error/timeout cells exit 3 under keep-going semantics (the grid
//!   still renders every row) and replay verbatim on `--resume`;
//! * an unusable `VEGA_CACHE_DIR` (a regular file where the directory
//!   should be) degrades both the store and the journal to counted
//!   warnings — the run completes in memory, byte-identical to a
//!   cache-off run, and never panics;
//! * `--shard 1/2` + `--shard 2/2` render disjoint row sets whose
//!   union is the serial grid, and `--merge 2` reassembles the exact
//!   serial-order bytes from the shard journals;
//! * the `faults` and `lifecycle` grids resume through the same
//!   machinery — a SIGKILLed lifecycle grid (ISSUE 8) resumes
//!   byte-identically with its pre-kill cells served from the journal
//!   and the `.lfc` store tier.

use std::collections::HashSet;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::{Command, Output, Stdio};
use std::time::{Duration, Instant};

use vega::lifecycle::{self, LifecycleCmd};
use vega::sweep::explore::{self, GridFormat, GridSpec, Precision};
use vega::sweep::journal;

/// The acceptance grid: 9 cells (cores 1..=9 × int8), 2 DVFS rows each.
const GRID: &[&str] = &[
    "--cores", "1-9", "--precision", "int8", "--dvfs-steps", "2", "--format", "csv", "--jobs", "2",
];
const CELLS: u64 = 9;

/// The in-process twin of [`GRID`], for computing the journal identity.
fn grid_spec() -> GridSpec {
    GridSpec {
        cores: (1..=9).collect(),
        precisions: vec![Precision::Int8],
        dvfs_steps: 2,
        format: GridFormat::Csv,
    }
}

fn temp_dir(case: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("vega-resume-test-{}-{case}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

/// A `vega` command with a hermetic cache environment: the store and
/// journal both live under `cache`, and the variables the surrounding
/// shell (e.g. ci.sh) may have set cannot leak in.
fn vega(cache: &Path) -> Command {
    let mut c = Command::new(env!("CARGO_BIN_EXE_vega"));
    c.env("VEGA_CACHE_DIR", cache).env_remove("VEGA_CACHE").env_remove("VEGA_CELL_DELAY_MS");
    c
}

fn sweep(cache: &Path, extra: &[&str]) -> Output {
    vega(cache).arg("sweep").args(GRID).args(extra).output().expect("run vega sweep")
}

fn stdout(o: &Output) -> String {
    String::from_utf8(o.stdout.clone()).expect("utf-8 stdout")
}

fn stderr(o: &Output) -> String {
    String::from_utf8(o.stderr.clone()).expect("utf-8 stderr")
}

/// Path of the (unsharded) journal file for [`GRID`] under `cache`.
fn journal_path(cache: &Path) -> PathBuf {
    let key = explore::grid_key(&grid_spec());
    cache.join("journals").join(format!("j{key:016x}.jnl"))
}

/// Valid (checksummed, well-formed) records currently in the journal.
fn journal_records(cache: &Path) -> u64 {
    let key = explore::grid_key(&grid_spec());
    let grid_id = format!("sweep:{key:016x}");
    fs::read(journal_path(cache))
        .ok()
        .and_then(|bytes| journal::replay(&bytes, &grid_id, None))
        .map_or(0, |(records, _)| records.len() as u64)
}

/// Completed `.sim` store entries under `cache` (entry writes are
/// tmp-file + atomic rename, so a present `.sim` file is never torn).
fn sim_entries(cache: &Path) -> u64 {
    fs::read_dir(cache).map_or(0, |d| {
        d.filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "sim"))
            .count() as u64
    })
}

/// The data rows (everything after the CSV header) of a rendered grid.
fn data_rows(text: &str) -> HashSet<String> {
    text.lines().skip(1).map(str::to_string).collect()
}

/// Acceptance (a): SIGKILL a sweep mid-grid, `--resume`, and get the
/// bytes an uninterrupted run produces — with the pre-kill work served
/// from the journal + store instead of recomputed, counted exactly.
#[test]
fn kill_mid_grid_then_resume_is_byte_identical_with_exact_counters() {
    let ref_dir = temp_dir("kill-ref");
    let reference = sweep(&ref_dir, &[]);
    assert!(reference.status.success(), "reference run failed: {}", stderr(&reference));
    let expected = stdout(&reference);
    assert_eq!(expected.lines().count() as u64, 1 + CELLS * 2, "header + 2 DVFS rows per cell");

    // The victim: per-cell delay widens the kill window to ~150 ms per
    // cell, so the poll below reliably catches it mid-grid.
    let dir = temp_dir("kill");
    let mut child = vega(&dir)
        .arg("sweep")
        .args(GRID)
        .env("VEGA_CELL_DELAY_MS", "150")
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn victim");
    let deadline = Instant::now() + Duration::from_secs(60);
    while journal_records(&dir) < 2 && Instant::now() < deadline {
        if child.try_wait().expect("try_wait").is_some() {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let _ = child.kill(); // SIGKILL on unix: no cleanup handler runs
    let _ = child.wait();

    let journaled = journal_records(&dir);
    let persisted = sim_entries(&dir);
    assert!(journaled >= 2, "child journaled only {journaled} cells before the kill");
    assert!(
        journaled <= persisted && persisted <= CELLS,
        "a journal record implies a persisted entry (journaled {journaled}, persisted {persisted})"
    );

    // Resume: journaled cells replay (their recomputation is a disk
    // hit), the rest run live and get journaled; the bytes match the
    // uninterrupted run exactly.
    let resumed = sweep(&dir, &["--resume", "--stats"]);
    assert!(resumed.status.success(), "resume failed: {}", stderr(&resumed));
    assert_eq!(stdout(&resumed), expected, "resumed output must be byte-identical");
    let log = stderr(&resumed);
    for needle in [
        format!("sims: 0 hits / {CELLS} misses"),
        format!(
            "disk: {persisted} hits / {} misses / {} writes / 0 write-errors",
            CELLS - persisted,
            CELLS - persisted
        ),
        format!("journal: {journaled} prior / {} recorded / 0 write-errors", CELLS - journaled),
    ] {
        assert!(log.contains(&needle), "resume stats missing '{needle}':\n{log}");
    }

    // A second resume finds the whole grid journaled and on disk.
    let again = sweep(&dir, &["--resume", "--stats"]);
    assert!(again.status.success());
    assert_eq!(stdout(&again), expected, "second resume must be byte-identical");
    let log = stderr(&again);
    for needle in [
        format!("disk: {CELLS} hits / 0 misses / 0 writes / 0 write-errors"),
        format!("journal: {CELLS} prior / 0 recorded / 0 write-errors"),
    ] {
        assert!(log.contains(&needle), "second-resume stats missing '{needle}':\n{log}");
    }

    let _ = fs::remove_dir_all(&ref_dir);
    let _ = fs::remove_dir_all(&dir);
}

/// Acceptance (a), adversarial half: a torn trailing record costs
/// exactly its one cell (recomputed, re-journaled), and garbage
/// *appended* to a valid journal costs nothing — both resumes render
/// the exact bytes of the undamaged run.
#[test]
fn torn_tail_and_trailing_garbage_never_corrupt_a_resume() {
    let dir = temp_dir("torn");
    let full = sweep(&dir, &["--stats"]);
    assert!(full.status.success(), "seed run failed: {}", stderr(&full));
    let expected = stdout(&full);
    assert!(stderr(&full).contains(&format!("journal: 0 prior / {CELLS} recorded")));

    // Tear the last record the way SIGKILL mid-append does.
    let path = journal_path(&dir);
    let bytes = fs::read(&path).unwrap();
    fs::write(&path, &bytes[..bytes.len() - 7]).unwrap();
    assert_eq!(journal_records(&dir), CELLS - 1, "the torn record reads as not-done");

    let resumed = sweep(&dir, &["--resume", "--stats"]);
    assert!(resumed.status.success());
    assert_eq!(stdout(&resumed), expected, "torn-tail resume must be byte-identical");
    let log = stderr(&resumed);
    for needle in [
        format!("journal: {} prior / 1 recorded / 0 write-errors", CELLS - 1),
        format!("disk: {CELLS} hits / 0 misses / 0 writes"),
    ] {
        assert!(log.contains(&needle), "torn-tail stats missing '{needle}':\n{log}");
    }

    // The journal is whole again (the resume truncated the tear and
    // re-appended the lost cell); garbage after it is ignored.
    let mut bytes = fs::read(&path).unwrap();
    bytes.extend_from_slice(&[0xFF; 13]);
    fs::write(&path, &bytes).unwrap();
    let resumed = sweep(&dir, &["--resume", "--stats"]);
    assert!(resumed.status.success());
    assert_eq!(stdout(&resumed), expected, "garbage-tail resume must be byte-identical");
    assert!(
        stderr(&resumed).contains(&format!("journal: {CELLS} prior / 0 recorded / 0 write-errors")),
        "garbage tail must cost nothing:\n{}",
        stderr(&resumed)
    );

    let _ = fs::remove_dir_all(&dir);
}

/// Satellite (a): keep-going semantics. A grid whose cells end in
/// error/timeout still renders every row, but the process exits 3 so CI
/// cannot green a half-failed grid — and the failed cells are journaled,
/// replaying their status rows verbatim on `--resume` (still exit 3).
#[test]
fn failed_cells_render_but_exit_3_and_replay_on_resume() {
    let dir = temp_dir("exit3");
    let out = sweep(&dir, &["--timeout-ms", "0"]);
    assert_eq!(out.status.code(), Some(3), "failed cells must exit 3: {}", stderr(&out));
    let text = stdout(&out);
    assert_eq!(text.lines().count() as u64, 1 + CELLS, "one status row per timed-out cell");
    assert!(text.contains("timeout after 0 ms"), "status rows carry the timeout:\n{text}");
    assert!(
        stderr(&out).contains(&format!("{CELLS} cell(s) ended in error/timeout")),
        "stderr names the damage:\n{}",
        stderr(&out)
    );

    let resumed = sweep(&dir, &["--resume", "--stats"]);
    assert_eq!(resumed.status.code(), Some(3), "replayed failures still exit 3");
    assert_eq!(stdout(&resumed), text, "replayed status rows must be byte-identical");
    assert!(
        stderr(&resumed).contains(&format!("journal: {CELLS} prior / 0 recorded")),
        "failed cells replay from the journal:\n{}",
        stderr(&resumed)
    );

    let _ = fs::remove_dir_all(&dir);
}

/// Acceptance (c): `VEGA_CACHE_DIR` pointing at a regular file (so
/// neither the store directory nor the journal directory can exist)
/// degrades to a completed in-memory run — byte-identical to a healthy
/// run, warnings counted, never a panic. Works under any uid: opening
/// a file as a directory fails even for root, where read-only
/// permission bits do not.
#[test]
fn unusable_cache_dir_degrades_to_a_completed_in_memory_run() {
    let ref_dir = temp_dir("degraded-ref");
    let reference = sweep(&ref_dir, &[]);
    assert!(reference.status.success());

    let dir = temp_dir("degraded");
    fs::create_dir_all(dir.parent().unwrap()).unwrap();
    fs::write(&dir, b"a file where the cache dir should be").unwrap();
    let out = sweep(&dir, &["--stats"]);
    assert!(out.status.success(), "degraded run must complete: {}", stderr(&out));
    assert_eq!(stdout(&out), stdout(&reference), "degraded run must be byte-identical");
    let log = stderr(&out);
    assert!(log.contains("disabled"), "store and journal warn once each:\n{log}");
    for needle in ["disk: off", "journal: 0 prior / 0 recorded / 1 write-errors"] {
        assert!(log.contains(needle), "degraded stats missing '{needle}':\n{log}");
    }

    let _ = fs::remove_dir_all(&ref_dir);
    let _ = fs::remove_file(&dir);
}

/// Acceptance (b): two shards of the same grid render disjoint data-row
/// sets whose union is the serial grid, and `--merge 2` over their
/// journals (plus the shared store) reassembles the exact serial bytes.
#[test]
fn shards_partition_the_grid_and_merge_reassembles_serial_bytes() {
    let ref_dir = temp_dir("shard-ref");
    let reference = sweep(&ref_dir, &[]);
    assert!(reference.status.success());
    let expected = stdout(&reference);

    let dir = temp_dir("shard");
    let s1 = sweep(&dir, &["--shard", "1/2"]);
    let s2 = sweep(&dir, &["--shard", "2/2"]);
    assert!(s1.status.success() && s2.status.success());
    let (r1, r2) = (data_rows(&stdout(&s1)), data_rows(&stdout(&s2)));
    let all = data_rows(&expected);
    assert!(r1.is_disjoint(&r2), "shard row sets must be disjoint");
    assert_eq!(r1.len() + r2.len(), all.len(), "shards must cover the grid exactly");
    assert_eq!(r1.union(&r2).cloned().collect::<HashSet<_>>(), all);

    let merged = sweep(&dir, &["--merge", "2", "--stats"]);
    assert!(merged.status.success(), "merge failed: {}", stderr(&merged));
    assert_eq!(stdout(&merged), expected, "merge must reassemble the serial bytes");
    let log = stderr(&merged);
    for needle in [
        format!("journal: {CELLS} prior / 0 recorded / 0 write-errors"),
        format!("disk: {CELLS} hits / 0 misses / 0 writes"),
    ] {
        assert!(log.contains(&needle), "merge stats missing '{needle}':\n{log}");
    }

    // The parser rejects modes that contradict each other.
    let bad = sweep(&dir, &["--merge", "2", "--resume"]);
    assert_eq!(bad.status.code(), Some(2), "--merge with --resume is a usage error");

    let _ = fs::remove_dir_all(&ref_dir);
    let _ = fs::remove_dir_all(&dir);
}

/// ISSUE 8 acceptance: the lifecycle grid survives a mid-grid SIGKILL
/// the way the sweep does — `--resume` renders the exact bytes of an
/// uninterrupted run, pre-kill cells served from the journal + the
/// `.lfc` store tier, counted exactly.
#[test]
fn lifecycle_kill_mid_grid_then_resume_is_byte_identical() {
    const LC_GRID: &[&str] = &[
        "--kernel", "matmul-i8", "--cores", "2", "--seed", "1", "--duration-s", "600",
        "--rates", "0.05,0.2", "--duty", "eager", "--sleep", "cognitive,retentive",
        "--boot", "l2,mram", "--format", "csv", "--jobs", "2",
    ];
    const LC_CELLS: u64 = 8; // 2 rates x 1 duty x 2 sleeps x 2 boots

    let lc_journal_records = |cache: &Path| -> u64 {
        let args: Vec<String> = LC_GRID.iter().map(|s| s.to_string()).collect();
        let key = lifecycle::grid_key(&LifecycleCmd::parse(&args).expect("grid args parse"));
        let grid_id = format!("lifecycle:{key:016x}");
        fs::read(cache.join("journals").join(format!("j{key:016x}.jnl")))
            .ok()
            .and_then(|bytes| journal::replay(&bytes, &grid_id, None))
            .map_or(0, |(records, _)| records.len() as u64)
    };
    let lfc_entries = |cache: &Path| -> u64 {
        fs::read_dir(cache).map_or(0, |d| {
            d.filter_map(|e| e.ok())
                .filter(|e| e.path().extension().is_some_and(|x| x == "lfc"))
                .count() as u64
        })
    };
    let run = |cache: &Path, extra: &[&str]| -> Output {
        vega(cache).arg("lifecycle").args(LC_GRID).args(extra).output().expect("run vega lifecycle")
    };

    let ref_dir = temp_dir("lc-kill-ref");
    let reference = run(&ref_dir, &[]);
    assert!(reference.status.success(), "reference run failed: {}", stderr(&reference));
    let expected = stdout(&reference);
    assert_eq!(expected.lines().count() as u64, 1 + LC_CELLS, "header + one row per cell");

    let dir = temp_dir("lc-kill");
    let mut child = vega(&dir)
        .arg("lifecycle")
        .args(LC_GRID)
        .env("VEGA_CELL_DELAY_MS", "150")
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn victim");
    let deadline = Instant::now() + Duration::from_secs(60);
    while lc_journal_records(&dir) < 2 && Instant::now() < deadline {
        if child.try_wait().expect("try_wait").is_some() {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let _ = child.kill();
    let _ = child.wait();

    let journaled = lc_journal_records(&dir);
    let persisted = lfc_entries(&dir);
    assert!(journaled >= 2, "child journaled only {journaled} cells before the kill");
    assert!(
        journaled <= persisted && persisted <= LC_CELLS,
        "a journal record implies a persisted .lfc entry (journaled {journaled}, persisted {persisted})"
    );

    let resumed = run(&dir, &["--resume", "--stats"]);
    assert!(resumed.status.success(), "resume failed: {}", stderr(&resumed));
    assert_eq!(stdout(&resumed), expected, "resumed output must be byte-identical");
    let log = stderr(&resumed);
    for needle in [
        format!(
            "disk(lfc): {persisted} hits / {} misses / {} writes / 0 write-errors",
            LC_CELLS - persisted,
            LC_CELLS - persisted
        ),
        format!("journal: {journaled} prior / {} recorded / 0 write-errors", LC_CELLS - journaled),
    ] {
        assert!(log.contains(&needle), "resume stats missing '{needle}':\n{log}");
    }

    let again = run(&dir, &["--resume", "--stats"]);
    assert!(again.status.success());
    assert_eq!(stdout(&again), expected, "second resume must be byte-identical");
    let log = stderr(&again);
    for needle in [
        format!("disk(lfc): {LC_CELLS} hits / 0 misses / 0 writes / 0 write-errors"),
        format!("journal: {LC_CELLS} prior / 0 recorded / 0 write-errors"),
    ] {
        assert!(log.contains(&needle), "second-resume stats missing '{needle}':\n{log}");
    }

    let _ = fs::remove_dir_all(&ref_dir);
    let _ = fs::remove_dir_all(&dir);
}

/// The fault grid resumes through the same machinery: a completed
/// campaign grid replays entirely from its journal, with the `.flt`
/// store tier serving every recomputation.
#[test]
fn faults_grid_resumes_from_its_journal() {
    let dir = temp_dir("faults");
    let args = [
        "--kernel", "matmul-f32", "--cores", "8", "--seeds", "7,8", "--rates", "1e-5,2e-5",
        "--tiers", "mram", "--sleep-s", "3600", "--format", "csv",
    ];
    let first = vega(&dir).arg("faults").args(args).output().expect("run vega faults");
    assert!(first.status.success(), "faults run failed: {}", stderr(&first));

    let resumed =
        vega(&dir).arg("faults").args(args).args(["--resume", "--stats"]).output().unwrap();
    assert!(resumed.status.success(), "faults resume failed: {}", stderr(&resumed));
    assert_eq!(stdout(&resumed), stdout(&first), "resumed fault grid must be byte-identical");
    let log = stderr(&resumed);
    for needle in [
        "journal: 4 prior / 0 recorded / 0 write-errors",
        "disk(flt): 4 hits / 0 misses / 0 writes",
    ] {
        assert!(log.contains(needle), "faults resume stats missing '{needle}':\n{log}");
    }

    let _ = fs::remove_dir_all(&dir);
}
