//! Golden-vector and property tests for the explicit ISA byte encoding
//! (ISSUE 4 acceptance: `Program::content_hash` — and therefore every
//! persisted cache key — is computed from bytes *this crate* defines).
//!
//! The golden vectors below are the stability contract: if any of them
//! changes, every on-disk cache entry in the world is orphaned. That can
//! be a legitimate, deliberate choice (bump `ISA_ENCODING_VERSION` and
//! update the vectors in the same commit) — it must never be an
//! accident, which is exactly what these hard-coded bytes catch in CI.

use vega::common::{property, Rng};
use vega::isa::{
    encode, AluOp, Asm, Cond, FpFmt, FpOp, Inst, LoopCount, MemSize, Program, SimdFmt, SimdOp,
    ISA_ENCODING_VERSION,
};

/// Every `Inst` variant encodes to exactly these bytes (opcode table of
/// `isa/encode.rs`). One entry per variant, both `LoopCount` forms.
#[test]
fn golden_byte_vectors_for_every_variant() {
    let cases: [(Inst, &[u8]); 19] = [
        (
            Inst::Alu { op: AluOp::Add, rd: 1, rs1: 2, rs2: 3 },
            &[0x01, 0, 1, 2, 3],
        ),
        (
            Inst::Alu { op: AluOp::Clip, rd: 31, rs1: 30, rs2: 29 },
            &[0x01, 19, 31, 30, 29],
        ),
        (
            Inst::AluImm { op: AluOp::Sra, rd: 5, rs1: 6, imm: -2 },
            &[0x02, 4, 5, 6, 0xFE, 0xFF, 0xFF, 0xFF],
        ),
        (Inst::Li { rd: 10, imm: 64 }, &[0x03, 10, 64, 0, 0, 0]),
        (
            Inst::Load { size: MemSize::W, rd: 11, rs1: 10, imm: 4, post_inc: true },
            &[0x04, 4, 11, 10, 4, 0, 0, 0, 1],
        ),
        (
            Inst::Store { size: MemSize::Hu, rs2: 7, rs1: 8, imm: -8, post_inc: false },
            &[0x05, 3, 7, 8, 0xF8, 0xFF, 0xFF, 0xFF, 0],
        ),
        (
            Inst::Branch { cond: Cond::Geu, rs1: 1, rs2: 2, target: 300 },
            &[0x06, 5, 1, 2, 0x2C, 0x01, 0, 0],
        ),
        (Inst::Jal { rd: 0, target: 7 }, &[0x07, 0, 7, 0, 0, 0]),
        (Inst::Jalr { rd: 1, rs1: 2 }, &[0x08, 1, 2]),
        (Inst::Mac { rd: 12, rs1: 11, rs2: 11 }, &[0x09, 12, 11, 11]),
        (Inst::Msu { rd: 4, rs1: 5, rs2: 6 }, &[0x0A, 4, 5, 6]),
        (
            Inst::Simd { op: SimdOp::SDotSp, fmt: SimdFmt::B4, rd: 1, rs1: 2, rs2: 3 },
            &[0x0B, 5, 0, 1, 2, 3],
        ),
        (
            Inst::LpSetup { lp: 0, count: LoopCount::Imm(4), body_end: 4 },
            &[0x0C, 0, 0, 4, 0, 0, 0, 4, 0, 0, 0],
        ),
        (
            Inst::LpSetup { lp: 1, count: LoopCount::Reg(9), body_end: 12 },
            &[0x0C, 1, 1, 9, 0, 0, 0, 12, 0, 0, 0],
        ),
        (
            Inst::Fp { op: FpOp::DotpEx, fmt: FpFmt::VH, rd: 1, rs1: 2, rs2: 3 },
            &[0x0D, 19, 3, 1, 2, 3],
        ),
        // fp8 SIMD (vfdotpex.s.b): appended fmt code 5, everything else
        // unchanged — the additive-extension contract of ISSUE 5.
        (
            Inst::Fp { op: FpOp::DotpEx, fmt: FpFmt::VB4, rd: 1, rs1: 2, rs2: 3 },
            &[0x0D, 19, 5, 1, 2, 3],
        ),
        (Inst::Barrier, &[0x0E]),
        (Inst::Halt, &[0x0F]),
        (Inst::Nop, &[0x10]),
    ];
    for (inst, want) in cases {
        assert_eq!(inst.encode(), want, "{inst:?}");
    }
}

/// Every operand enum's wire codes, exhaustively (append-only contract).
#[test]
fn golden_operand_codes() {
    let alu = [
        AluOp::Add,
        AluOp::Sub,
        AluOp::Sll,
        AluOp::Srl,
        AluOp::Sra,
        AluOp::And,
        AluOp::Or,
        AluOp::Xor,
        AluOp::Slt,
        AluOp::Sltu,
        AluOp::Mul,
        AluOp::Mulh,
        AluOp::Div,
        AluOp::Divu,
        AluOp::Rem,
        AluOp::Remu,
        AluOp::Min,
        AluOp::Max,
        AluOp::Abs,
        AluOp::Clip,
    ];
    for (i, op) in alu.into_iter().enumerate() {
        assert_eq!(op.code() as usize, i, "{op:?}");
    }
    let fp = [
        FpOp::Add,
        FpOp::Sub,
        FpOp::Mul,
        FpOp::Madd,
        FpOp::Msub,
        FpOp::Min,
        FpOp::Max,
        FpOp::Div,
        FpOp::Sqrt,
        FpOp::Abs,
        FpOp::Neg,
        FpOp::CmpLt,
        FpOp::CmpLe,
        FpOp::CmpEq,
        FpOp::CvtIF,
        FpOp::CvtFI,
        FpOp::CvtSH2,
        FpOp::CvtH2S0,
        FpOp::CvtH2S1,
        FpOp::DotpEx,
    ];
    for (i, op) in fp.into_iter().enumerate() {
        assert_eq!(op.code() as usize, i, "{op:?}");
    }
    let cond = [Cond::Eq, Cond::Ne, Cond::Lt, Cond::Ge, Cond::Ltu, Cond::Geu];
    for (i, c) in cond.into_iter().enumerate() {
        assert_eq!(c.code() as usize, i, "{c:?}");
    }
    let mem = [MemSize::B, MemSize::Bu, MemSize::H, MemSize::Hu, MemSize::W];
    for (i, m) in mem.into_iter().enumerate() {
        assert_eq!(m.code() as usize, i, "{m:?}");
    }
    let simd = [
        SimdOp::Add,
        SimdOp::Sub,
        SimdOp::Min,
        SimdOp::Max,
        SimdOp::Avg,
        SimdOp::SDotSp,
        SimdOp::SDotUp,
        SimdOp::Pack,
    ];
    for (i, s) in simd.into_iter().enumerate() {
        assert_eq!(s.code() as usize, i, "{s:?}");
    }
    assert_eq!([SimdFmt::B4.code(), SimdFmt::H2.code()], [0, 1]);
    assert_eq!(
        [
            FpFmt::S.code(),
            FpFmt::H.code(),
            FpFmt::B.code(),
            FpFmt::VH.code(),
            FpFmt::VB.code(),
            FpFmt::VB4.code(),
        ],
        [0, 1, 2, 3, 4, 5]
    );
}

/// The key-stability gate: hard-coded content hashes. These exact values
/// must come out of any build, on any toolchain, forever (or the change
/// is a deliberate `ISA_ENCODING_VERSION` bump updating this test).
#[test]
fn golden_content_hashes() {
    assert_eq!(ISA_ENCODING_VERSION, 1);

    let golden = Program {
        insts: vec![
            Inst::Li { rd: 10, imm: 64 },
            Inst::LpSetup { lp: 0, count: LoopCount::Imm(4), body_end: 4 },
            Inst::Load { size: MemSize::W, rd: 11, rs1: 10, imm: 4, post_inc: true },
            Inst::Mac { rd: 12, rs1: 11, rs2: 11 },
            Inst::Barrier,
            Inst::Halt,
        ],
        name: "golden".into(),
    };
    // Framing: version LE, count LE, then the per-variant golden bytes.
    let stream = encode::encode_stream(&golden.insts);
    assert_eq!(&stream[..4], &1u32.to_le_bytes());
    assert_eq!(&stream[4..8], &6u32.to_le_bytes());
    assert_eq!(golden.content_hash(), 0xfe5fcddbd6f7b66f);

    let empty = Program { insts: vec![], name: "empty".into() };
    assert_eq!(empty.content_hash(), 0x89cd31291d2aefa4);

    let nop = Program { insts: vec![Inst::Nop], name: "nop".into() };
    assert_eq!(nop.content_hash(), 0x5f4900070d4482df);
}

/// The fp8 extension's own golden hashes (cross-computed offline in
/// Python like the PR 4 set). These freeze the `FpFmt::VB4 = 5` wire
/// code: any accidental renumbering of the fp8 format — or any byte
/// drift in the shared framing — fails here before it can orphan or
/// corrupt persisted fp8 cache entries.
#[test]
fn golden_fp8_content_hashes() {
    let solo = Program {
        insts: vec![Inst::Fp { op: FpOp::DotpEx, fmt: FpFmt::VB4, rd: 1, rs1: 2, rs2: 3 }],
        name: "fp8-solo".into(),
    };
    assert_eq!(solo.content_hash(), 0x1477abe1c2d9f6c4);

    let prog = Program {
        insts: vec![
            Inst::Li { rd: 10, imm: 64 },
            Inst::Fp { op: FpOp::DotpEx, fmt: FpFmt::VB4, rd: 1, rs1: 2, rs2: 3 },
            Inst::Halt,
        ],
        name: "fp8-golden".into(),
    };
    assert_eq!(prog.content_hash(), 0x271a8b7d8addc0b4);
}

/// The name is display metadata, not key material: two programs with the
/// same instruction stream share a content hash.
#[test]
fn content_hash_ignores_the_program_name() {
    let a = Program { insts: vec![Inst::Halt], name: "a".into() };
    let b = Program { insts: vec![Inst::Halt], name: "b".into() };
    assert_eq!(a.content_hash(), b.content_hash());
}

fn rand_reg(rng: &mut Rng) -> u8 {
    rng.below(32) as u8
}

fn rand_inst(rng: &mut Rng) -> Inst {
    let (rd, rs1, rs2) = (rand_reg(rng), rand_reg(rng), rand_reg(rng));
    let imm = rng.range_i64(-4096, 4096) as i32;
    let target = rng.below(1024) as usize;
    match rng.below(18) {
        0 => Inst::Alu { op: AluOp::Add, rd, rs1, rs2 },
        1 => Inst::AluImm { op: AluOp::And, rd, rs1, imm },
        2 => Inst::Li { rd, imm },
        3 => Inst::Load { size: MemSize::W, rd, rs1, imm, post_inc: rng.bool() },
        4 => Inst::Store { size: MemSize::H, rs2, rs1, imm, post_inc: rng.bool() },
        5 => Inst::Branch { cond: Cond::Ne, rs1, rs2, target },
        6 => Inst::Jal { rd, target },
        7 => Inst::Jalr { rd, rs1 },
        8 => Inst::Mac { rd, rs1, rs2 },
        9 => Inst::Msu { rd, rs1, rs2 },
        10 => Inst::Simd { op: SimdOp::SDotSp, fmt: SimdFmt::B4, rd, rs1, rs2 },
        11 => Inst::LpSetup {
            lp: rng.below(2) as u8,
            count: if rng.bool() {
                LoopCount::Imm(rng.below(256) as u32)
            } else {
                LoopCount::Reg(rand_reg(rng))
            },
            body_end: target,
        },
        12 => Inst::Fp { op: FpOp::Madd, fmt: FpFmt::S, rd, rs1, rs2 },
        13 => Inst::Fp { op: FpOp::DotpEx, fmt: FpFmt::VH, rd, rs1, rs2 },
        14 => Inst::Fp { op: FpOp::DotpEx, fmt: FpFmt::VB4, rd, rs1, rs2 },
        15 => Inst::Barrier,
        16 => Inst::Halt,
        _ => Inst::Nop,
    }
}

/// Injectivity: distinct instruction streams encode to distinct byte
/// streams (the property that makes the content hash a sound key; a
/// collision would need FNV itself to collide, never the encoding).
#[test]
fn encode_is_injective_on_distinct_streams() {
    property("isa-encode-injective", 200, |rng| {
        let a: Vec<Inst> = (0..rng.below(20) as usize).map(|_| rand_inst(rng)).collect();
        let b: Vec<Inst> = (0..rng.below(20) as usize).map(|_| rand_inst(rng)).collect();
        let ea = encode::encode_stream(&a);
        let eb = encode::encode_stream(&b);
        assert_eq!(a == b, ea == eb, "streams {a:?} vs {b:?}");
        // Single-instruction check with sharper probability of near-miss
        // pairs: mutate one field and require a byte-level difference.
        if let Some(&first) = a.first() {
            let mut out = Vec::new();
            first.encode_into(&mut out);
            assert_eq!(out, first.encode());
        }
    });
}

/// The kernel library's real programs all hash distinctly (a smoke that
/// the key space is not degenerate end-to-end).
#[test]
fn real_kernel_programs_hash_distinctly() {
    use vega::kernels::fp_matmul::{self, FpWidth};
    use vega::kernels::int_matmul::{self, IntWidth};
    let progs = [
        int_matmul::build(64, 64, 64, IntWidth::I8),
        int_matmul::build(64, 64, 64, IntWidth::I16),
        int_matmul::build(64, 64, 64, IntWidth::I32),
        fp_matmul::build(32, 32, 64, FpWidth::F32),
        fp_matmul::build(32, 32, 64, FpWidth::F16x2),
        fp_matmul::build(32, 32, 64, FpWidth::F8x4),
    ];
    let mut hashes: Vec<u64> = progs.iter().map(|p| p.content_hash()).collect();
    hashes.sort_unstable();
    hashes.dedup();
    assert_eq!(hashes.len(), progs.len(), "kernel content hashes must be distinct");
}

/// Assembling the same source twice yields identical hashes (determinism
/// within a process is the baseline the cross-toolchain golden vectors
/// build on).
#[test]
fn assembly_is_hash_deterministic() {
    let build = || {
        let mut a = Asm::new("det");
        let end = a.label();
        a.li(10, 16);
        a.lp_setup_imm(0, 8, end);
        a.mac(12, 11, 11);
        a.bind(end);
        a.halt();
        a.finish().unwrap()
    };
    assert_eq!(build().content_hash(), build().content_hash());
}
