//! DNN stack integration: pipeline properties across networks, policies
//! and engines (the Fig. 9–11 / Table VII machinery).

use vega::dnn::{
    mobilenet_v2, repvgg, run_network, tile_layer, Bound, PipelineConfig, StorePolicy, Variant,
    WeightStore, L1_BUDGET,
};
use vega::power;

#[test]
fn greedy_policy_fills_mram_front_to_back() {
    let net = repvgg(Variant::A1);
    let rep = run_network(&net, PipelineConfig::nominal_sw(StorePolicy::GreedyMram));
    let split = rep.mram_up_to.expect("A1 exceeds MRAM");
    // Weight-bearing layers before the split in MRAM; after, HyperRAM.
    for (i, l) in rep.layers.iter().enumerate() {
        if l.weight_bytes == 0 {
            continue;
        }
        if i <= split {
            assert_eq!(l.store, WeightStore::Mram, "{}", l.name);
        }
    }
    let hyper_layers =
        rep.layers.iter().filter(|l| l.store == WeightStore::HyperRam).count();
    assert!(hyper_layers >= 1, "some layers must spill to HyperRAM");
    // MRAM capacity respected.
    let mram_bytes: u64 = rep
        .layers
        .iter()
        .filter(|l| l.store == WeightStore::Mram)
        .map(|l| l.weight_bytes)
        .sum();
    assert!(mram_bytes <= 4 * 1024 * 1024);
}

#[test]
fn store_policy_changes_energy_not_compute() {
    let net = mobilenet_v2();
    let m = run_network(&net, PipelineConfig::nominal_sw(StorePolicy::AllMram));
    let h = run_network(&net, PipelineConfig::nominal_sw(StorePolicy::AllHyperRam));
    for (a, b) in m.layers.iter().zip(&h.layers) {
        assert_eq!(a.compute_cycles, b.compute_cycles, "{}", a.name);
        assert_eq!(a.l2l1_cycles, b.l2l1_cycles, "{}", a.name);
    }
    assert!(h.energy.hyperram_pj > 0.0 && h.energy.mram_pj == 0.0);
    assert!(m.energy.mram_pj > 0.0 && m.energy.hyperram_pj == 0.0);
}

#[test]
fn hwce_only_runs_conv_layers_entirely_on_engine() {
    let net = repvgg(Variant::A0);
    let rep = run_network(&net, PipelineConfig::table7_hwce(StorePolicy::GreedyMram));
    for l in &rep.layers {
        if l.name.contains("conv") {
            assert!(l.hwce_fraction > 0.99, "{}: frac {}", l.name, l.hwce_fraction);
        } else {
            assert_eq!(l.hwce_fraction, 0.0, "{}", l.name);
        }
    }
}

#[test]
fn hybrid_beats_both_pure_engines_on_repvgg() {
    let net = repvgg(Variant::A0);
    let mk = |engine| {
        run_network(
            &net,
            vega::dnn::PipelineConfig { op: power::HV, engine, policy: StorePolicy::GreedyMram },
        )
        .total_cycles()
    };
    let sw = mk(vega::dnn::Engine::Software);
    let only = mk(vega::dnn::Engine::HwceOnly);
    let hybrid = mk(vega::dnn::Engine::HwceHybrid);
    assert!(hybrid < only, "hybrid {hybrid} vs only {only}");
    assert!(hybrid < sw, "hybrid {hybrid} vs sw {sw}");
}

#[test]
fn tilings_respect_l1_for_every_evaluated_layer() {
    for net in [mobilenet_v2(), repvgg(Variant::A2)] {
        for l in &net.layers {
            let t = tile_layer(l, L1_BUDGET);
            assert!(2 * t.tile_bytes() <= L1_BUDGET as u64, "{}::{}", net.name, l.name);
        }
    }
}

#[test]
fn energy_breakdown_sums_to_total() {
    let net = mobilenet_v2();
    let rep = run_network(&net, PipelineConfig::nominal_sw(StorePolicy::AllMram));
    let e = &rep.energy;
    let sum = e.compute_pj + e.l2l1_pj + e.l1_pj + e.mram_pj + e.hyperram_pj;
    assert!((sum - e.total_pj()).abs() < 1.0);
    // Compute dominates on the MRAM flow (Fig. 11's message).
    assert!(e.compute_pj > 0.5 * e.total_pj());
    assert!(e.mram_pj < 0.1 * e.total_pj());
}

#[test]
fn faster_clock_reduces_latency_not_cycles() {
    let net = repvgg(Variant::A0);
    let slow = run_network(
        &net,
        vega::dnn::PipelineConfig {
            op: power::tables::DNN,
            engine: vega::dnn::Engine::Software,
            policy: StorePolicy::AllHyperRam,
        },
    );
    let fast = run_network(
        &net,
        vega::dnn::PipelineConfig {
            op: power::HV,
            engine: vega::dnn::Engine::Software,
            policy: StorePolicy::AllHyperRam,
        },
    );
    assert!(fast.latency_s() < slow.latency_s());
    // Compute cycles identical; only L3 cycles shift (same wall-clock
    // bandwidth at more cycles/second) — so totals differ somewhat, but
    // compute-bound layers match exactly.
    for (a, b) in slow.layers.iter().zip(&fast.layers) {
        assert_eq!(a.compute_cycles, b.compute_cycles);
    }
}

#[test]
fn final_fc_layer_is_l3_bound_everywhere() {
    for (net, policy) in [
        (mobilenet_v2(), StorePolicy::AllMram),
        (repvgg(Variant::A0), StorePolicy::GreedyMram),
    ] {
        let rep = run_network(&net, PipelineConfig::nominal_sw(policy));
        let fc = rep.layers.last().unwrap();
        assert_eq!(fc.bound, Bound::L3, "{}", net.name);
    }
}
