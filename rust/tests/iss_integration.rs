//! ISS integration: randomized program generation checked against a Rust
//! golden interpreter (the property-based layer over the unit tests).

use vega::common::{property, Rng};
use vega::isa::{Asm, A0, A1, A2, A3, T0, T1};
use vega::iss::{core::run_single_regs, FlatMem};

/// Random straight-line ALU programs must match a direct evaluation.
#[test]
fn random_alu_programs_match_golden() {
    property("alu-programs", 40, |rng: &mut Rng| {
        let regs = [A0, A1, A2, A3, T0, T1];
        let mut golden = [0u32; 32];
        for &r in &regs {
            golden[r as usize] = rng.next_u32();
        }
        let init: Vec<_> = regs.iter().map(|&r| (r, golden[r as usize])).collect();

        let mut a = Asm::new("rand");
        for _ in 0..30 {
            let rd = regs[rng.below(6) as usize];
            let rs1 = regs[rng.below(6) as usize];
            let rs2 = regs[rng.below(6) as usize];
            let (v1, v2) = (golden[rs1 as usize], golden[rs2 as usize]);
            let result = match rng.below(6) {
                0 => {
                    a.add(rd, rs1, rs2);
                    v1.wrapping_add(v2)
                }
                1 => {
                    a.sub(rd, rs1, rs2);
                    v1.wrapping_sub(v2)
                }
                2 => {
                    a.xor(rd, rs1, rs2);
                    v1 ^ v2
                }
                3 => {
                    a.and(rd, rs1, rs2);
                    v1 & v2
                }
                4 => {
                    a.mul(rd, rs1, rs2);
                    v1.wrapping_mul(v2)
                }
                _ => {
                    a.or(rd, rs1, rs2);
                    v1 | v2
                }
            };
            golden[rd as usize] = result;
        }
        a.halt();
        let prog = a.finish().unwrap();
        let mut mem = FlatMem::new(0, 64);
        let (_, got) = run_single_regs(&prog, &mut mem, &init, 10_000);
        for &r in &regs {
            assert_eq!(got[r as usize], golden[r as usize], "reg x{r}");
        }
    });
}

/// Memcpy through every load/store width and addressing mode.
#[test]
fn memcpy_all_widths() {
    for (loader, storer, step) in [(0u8, 0u8, 4i32), (1, 1, 2), (2, 2, 1)] {
        let mut a = Asm::new("memcpy");
        let end = a.label();
        a.lp_setup_imm(0, 16, end);
        match loader {
            0 => a.lw_pi(T0, A0, step),
            1 => a.lh_pi(T0, A0, step),
            _ => a.lb_pi(T0, A0, step),
        };
        match storer {
            0 => a.sw_pi(T0, A1, step),
            1 => a.sh_pi(T0, A1, step),
            _ => a.sb_pi(T0, A1, step),
        };
        a.bind(end);
        a.halt();
        let prog = a.finish().unwrap();
        let mut mem = FlatMem::new(0, 512);
        let src: Vec<u8> = (0..64u32).map(|i| (i * 7 + 3) as u8).collect();
        mem.write_bytes(0, &src);
        vega::iss::core::run_single(&prog, &mut mem, &[(A0, 0), (A1, 256)], 100_000);
        let n = 16 * step as usize;
        assert_eq!(mem.read_bytes(256, n), &src[..n], "width {step}");
    }
}

/// The classic sum loop with a data-dependent branch.
#[test]
fn branchy_sum_of_positive_elements() {
    let mut a = Asm::new("possum");
    let loop_top = a.label();
    let skip = a.label();
    let done = a.label();
    // A0 = ptr, A1 = count, A2 = acc
    a.li(A2, 0);
    a.bind(loop_top);
    a.beq(A1, 0, done);
    a.lw_pi(T0, A0, 4);
    a.blt(T0, 0, skip);
    a.add(A2, A2, T0);
    a.bind(skip);
    a.addi(A1, A1, -1);
    a.j(loop_top);
    a.bind(done);
    a.halt();
    let prog = a.finish().unwrap();

    let mut rng = Rng::new(3);
    let vals: Vec<i32> = (0..50).map(|_| rng.range_i64(-100, 100) as i32).collect();
    let want: i32 = vals.iter().filter(|&&v| v > 0).sum();
    let mut mem = FlatMem::new(0, 4096);
    mem.write_i32s(0, &vals);
    let (_, regs) =
        run_single_regs(&prog, &mut mem, &[(A0, 0), (A1, 50)], 100_000);
    assert_eq!(regs[A2 as usize] as i32, want);
}

/// Cycle counts are deterministic: same program, same input, same count.
#[test]
fn timing_is_deterministic() {
    let mut a = Asm::new("det");
    let end = a.label();
    a.lp_setup_imm(0, 100, end);
    a.lw(T0, A0, 0);
    a.mac(A2, T0, T0);
    a.bind(end);
    a.halt();
    let prog = a.finish().unwrap();
    let run = || {
        let mut mem = FlatMem::new(0, 64);
        mem.write_i32s(0, &[3]);
        vega::iss::core::run_single(&prog, &mut mem, &[(A0, 0)], 100_000).cycles
    };
    assert_eq!(run(), run());
}

/// Hardware loops beat branch-based loops on cycle count for the same
/// semantics (the Xpulp zero-overhead claim).
#[test]
fn hw_loops_beat_branches() {
    let body = |a: &mut Asm| {
        a.mac(A2, A0, A0);
    };
    let mut hw = Asm::new("hw");
    let end = hw.label();
    hw.lp_setup_imm(0, 500, end);
    body(&mut hw);
    hw.bind(end);
    hw.halt();

    let mut br = Asm::new("br");
    let top = br.label();
    let done = br.label();
    br.li(A1, 500);
    br.bind(top);
    br.beq(A1, 0, done);
    body(&mut br);
    br.addi(A1, A1, -1);
    br.j(top);
    br.bind(done);
    br.halt();

    let mut m1 = FlatMem::new(0, 64);
    let mut m2 = FlatMem::new(0, 64);
    let c_hw =
        vega::iss::core::run_single(&hw.finish().unwrap(), &mut m1, &[(A0, 3)], 1_000_000)
            .cycles;
    let c_br =
        vega::iss::core::run_single(&br.finish().unwrap(), &mut m2, &[(A0, 3)], 1_000_000)
            .cycles;
    assert!(
        (c_br as f64) > 3.0 * c_hw as f64,
        "hw {c_hw} vs branch {c_br}"
    );
}
