//! Sweep-engine invariants (ISSUE 2 acceptance):
//!
//! * reproduction reports are **byte-identical** at `--jobs 1` and
//!   `--jobs 8` — parallel fan-out may never change a paper number;
//! * the [`SimCache`] simulates each distinct (kernel, problem size,
//!   precision, core count, program hash) exactly once per engine — V/f
//!   sweeps and cross-report recurrences are served from the cache.

use std::collections::HashSet;

use vega::bench;
use vega::kernels::fp_matmul::FpWidth;
use vega::sweep::{Scenario, SimArena, SweepEngine};

/// (a) Byte-identical output for serial vs 8-way parallel engines, on the
/// three report shapes the issue names: a figure with a V/f sweep, a
/// table over the NSAA grid, and the ablation suite.
#[test]
fn repro_output_byte_identical_across_jobs() {
    for id in ["fig6", "table5", "ablations"] {
        let serial = bench::run_with(id, &SweepEngine::new(1)).unwrap();
        let parallel = bench::run_with(id, &SweepEngine::new(8)).unwrap();
        assert_eq!(serial, parallel, "{id}: --jobs 1 vs --jobs 8 output diverged");
    }
}

/// The suite runner (prefetch + parallel report rendering) produces the
/// same bytes as independent per-report runs, in paper order.
#[test]
fn run_many_matches_independent_runs() {
    let ids = ["table5", "fig6", "fig8", "table8", "fig9", "fig10", "fig11", "ablations"];
    let many = bench::run_many(&ids, &SweepEngine::new(8));
    for (id, got) in ids.iter().zip(many) {
        assert_eq!(got.unwrap(), bench::run(id).unwrap(), "{id} diverged under run_many");
    }
}

/// The network-report memo shares DNN pipeline runs across reports:
/// Figs. 9/10/11 all need MobileNetV2 `AllMram`, so after fig9 primes the
/// memo, fig10 adds only the `AllHyperRam` flow and fig11 adds nothing.
#[test]
fn network_runs_shared_across_reports() {
    let eng = SweepEngine::new(1);
    bench::run_with("fig9", &eng).unwrap();
    let (_, m_fig9) = eng.network_counters();
    assert_eq!(m_fig9, 1, "fig9 = one MobileNetV2 AllMram run");

    bench::run_with("fig10", &eng).unwrap();
    let (_, m_fig10) = eng.network_counters();
    assert_eq!(m_fig10 - m_fig9, 1, "fig10 adds only the AllHyperRam flow");

    bench::run_with("fig11", &eng).unwrap();
    let (hits, m_fig11) = eng.network_counters();
    assert_eq!(m_fig11, m_fig10, "fig11 is fully served from the memo");
    assert!(hits >= 3);
}

/// (b) Fig. 6 simulates each distinct program exactly once: the misses
/// equal the number of distinct cache keys in its declared grid, and the
/// Fig. 6b DVFS sweep is served from the cache (it reuses the 8-core int8
/// simulation — four operating points, zero extra simulations).
#[test]
fn fig6_vf_sweep_simulates_each_distinct_program_once() {
    let eng = SweepEngine::new(1);
    bench::run_with("fig6", &eng).unwrap();
    let distinct: HashSet<_> =
        bench::scenarios_for("fig6").iter().map(|s| s.key()).collect();
    let (hits, misses) = eng.cache().counters();
    assert_eq!(
        misses as usize,
        distinct.len(),
        "every distinct (kernel, size, precision, cores) simulates exactly once"
    );
    assert!(hits >= 1, "the DVFS sweep must reuse the cached 8-core int8 run");
    assert_eq!(eng.cache().len(), distinct.len());
}

/// Cross-report sharing: Table V's FP32 NSAA runs are reused verbatim by
/// Fig. 8, which only simulates the FP16 variants anew.
#[test]
fn cross_report_cache_sharing() {
    let eng = SweepEngine::new(1);
    bench::run_with("table5", &eng).unwrap();
    let (_, misses_after_t5) = eng.cache().counters();
    assert_eq!(misses_after_t5, 8, "table5 = 8 distinct kernel programs");

    bench::run_with("fig8", &eng).unwrap();
    let (hits, misses) = eng.cache().counters();
    assert_eq!(misses - misses_after_t5, 8, "fig8 only adds the 8 FP16 variants");
    assert!(hits >= 8, "fig8's FP32 side must come from table5's cache");
}

/// The cached result is the simulation's result: spot-check one scenario
/// against a direct arena run (stats and output digest).
#[test]
fn cached_results_match_direct_simulation() {
    let s = Scenario::Nsaa { name: "FIR", w: FpWidth::F32 };
    let eng = SweepEngine::new(1);
    let via_engine = eng.result(s);
    let direct = s.simulate(&mut SimArena::new());
    assert_eq!(via_engine.outputs_digest, direct.outputs_digest);
    assert_eq!(via_engine.run.stats, direct.run.stats);
    assert_eq!(via_engine.run.ops, direct.run.ops);
    assert_eq!(via_engine.run.name, direct.run.name);
}
