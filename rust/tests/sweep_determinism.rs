//! Sweep-engine invariants (ISSUE 2 acceptance):
//!
//! * reproduction reports are **byte-identical** at `--jobs 1` and
//!   `--jobs 8` — parallel fan-out may never change a paper number;
//! * the [`SimCache`] simulates each distinct (kernel, problem size,
//!   precision, core count, program hash) exactly once per engine — V/f
//!   sweeps and cross-report recurrences are served from the cache;
//! * (ISSUE 6) a panicking scenario in a work list yields one structured
//!   `SimError` cell at any `--jobs` value, while every other cell
//!   completes, matches a fault-free run, and the errored cell never
//!   pollutes the cache;
//! * (ISSUE 7) sharded sessions render pairwise-disjoint row subsets
//!   whose union is exactly the serial grid.

use std::collections::HashSet;

use vega::bench;
use vega::kernels::fp_matmul::FpWidth;
use vega::kernels::int_matmul::IntWidth;
use vega::sweep::explore::{self, GridFormat, GridSpec, Precision};
use vega::sweep::{GridSession, Scenario, ShardSpec, SimArena, SweepEngine};

/// (a) Byte-identical output for serial vs 8-way parallel engines, on the
/// three report shapes the issue names: a figure with a V/f sweep, a
/// table over the NSAA grid, and the ablation suite.
#[test]
fn repro_output_byte_identical_across_jobs() {
    for id in ["fig6", "table5", "ablations"] {
        let serial = bench::run_with(id, &SweepEngine::new(1)).unwrap();
        let parallel = bench::run_with(id, &SweepEngine::new(8)).unwrap();
        assert_eq!(serial, parallel, "{id}: --jobs 1 vs --jobs 8 output diverged");
    }
}

/// The suite runner (prefetch + parallel report rendering) produces the
/// same bytes as independent per-report runs, in paper order. The
/// independent runs use fresh in-memory engines (not `bench::run`'s
/// persistent global engine) so the comparison always exercises the live
/// simulator regardless of on-disk cache state.
#[test]
fn run_many_matches_independent_runs() {
    let ids = ["table5", "fig6", "fig8", "table8", "fig9", "fig10", "fig11", "ablations"];
    let many = bench::run_many(&ids, &SweepEngine::new(8));
    for (id, got) in ids.iter().zip(many) {
        let alone = bench::run_with(id, &SweepEngine::serial()).unwrap();
        assert_eq!(got.unwrap(), alone, "{id} diverged under run_many");
    }
}

/// The network-report memo shares DNN pipeline runs across reports:
/// Figs. 9/10/11 all need MobileNetV2 `AllMram`, so after fig9 primes the
/// memo, fig10 adds only the `AllHyperRam` flow and fig11 adds nothing.
#[test]
fn network_runs_shared_across_reports() {
    let eng = SweepEngine::new(1);
    bench::run_with("fig9", &eng).unwrap();
    let (_, m_fig9) = eng.network_counters();
    assert_eq!(m_fig9, 1, "fig9 = one MobileNetV2 AllMram run");

    bench::run_with("fig10", &eng).unwrap();
    let (_, m_fig10) = eng.network_counters();
    assert_eq!(m_fig10 - m_fig9, 1, "fig10 adds only the AllHyperRam flow");

    bench::run_with("fig11", &eng).unwrap();
    let (hits, m_fig11) = eng.network_counters();
    assert_eq!(m_fig11, m_fig10, "fig11 is fully served from the memo");
    assert!(hits >= 3);
}

/// (b) Fig. 6 simulates each distinct program exactly once: the misses
/// equal the number of distinct cache keys in its declared grid, and the
/// Fig. 6b DVFS sweep is served from the cache (it reuses the 8-core int8
/// simulation — four operating points, zero extra simulations).
#[test]
fn fig6_vf_sweep_simulates_each_distinct_program_once() {
    let eng = SweepEngine::new(1);
    bench::run_with("fig6", &eng).unwrap();
    let distinct: HashSet<_> =
        bench::scenarios_for("fig6").iter().map(|s| s.key()).collect();
    let (hits, misses) = eng.cache().counters();
    assert_eq!(
        misses as usize,
        distinct.len(),
        "every distinct (kernel, size, precision, cores) simulates exactly once"
    );
    assert!(hits >= 1, "the DVFS sweep must reuse the cached 8-core int8 run");
    assert_eq!(eng.cache().len(), distinct.len());
}

/// Cross-report sharing: Table V's FP32 NSAA runs are reused verbatim by
/// Fig. 8, which only simulates the FP16 variants anew.
#[test]
fn cross_report_cache_sharing() {
    let eng = SweepEngine::new(1);
    bench::run_with("table5", &eng).unwrap();
    let (_, misses_after_t5) = eng.cache().counters();
    assert_eq!(misses_after_t5, 8, "table5 = 8 distinct kernel programs");

    bench::run_with("fig8", &eng).unwrap();
    let (hits, misses) = eng.cache().counters();
    assert_eq!(misses - misses_after_t5, 8, "fig8 only adds the 8 FP16 variants");
    assert!(hits >= 8, "fig8's FP32 side must come from table5's cache");
}

/// `vega sweep` grids obey the same invariant as the reproduction
/// reports: byte-identical output at `--jobs 1` and `--jobs 8`, in every
/// render format (ISSUE 3 acceptance).
#[test]
fn sweep_grid_byte_identical_across_jobs() {
    let base = GridSpec {
        cores: vec![1, 2, 4, 8],
        precisions: vec![Precision::Int8, Precision::Fp16],
        dvfs_steps: 6,
        format: GridFormat::Csv,
    };
    // One engine per worker count, shared across formats: the renderers
    // read the same cached simulations, so only the first format pays.
    let eng1 = SweepEngine::new(1);
    let eng8 = SweepEngine::new(8);
    for format in [GridFormat::Csv, GridFormat::Markdown, GridFormat::Json] {
        let spec = GridSpec { format, ..base.clone() };
        let serial = explore::render(&eng1, &spec);
        let parallel = explore::render(&eng8, &spec);
        assert_eq!(serial, parallel, "{format:?}: --jobs 1 vs --jobs 8 grid diverged");
    }
}

/// fp8 grid cells (ISSUE 5): one simulation per fp8 (cores, precision)
/// cell, exact hit/miss counts through prefetch, render and re-render.
#[test]
fn fp8_grid_one_simulation_per_cell_with_exact_counters() {
    let spec = GridSpec {
        cores: vec![1, 2, 4, 8],
        precisions: vec![Precision::Fp8],
        dvfs_steps: 4,
        format: GridFormat::Csv,
    };
    let eng = SweepEngine::new(1);
    let first = explore::render(&eng, &spec);
    let (hits0, misses0) = eng.cache().counters();
    assert_eq!(misses0, 4, "one simulation per fp8 (cores, precision) cell");
    assert_eq!(hits0, 4, "rendering reads each prefetched cell back as a hit");
    let second = explore::render(&eng, &spec);
    assert_eq!(first, second, "re-render must be byte-identical");
    let (hits1, misses1) = eng.cache().counters();
    assert_eq!(misses1, 4, "re-render must not resimulate any fp8 cell");
    assert_eq!(hits1, 12, "second render is fully cache-served (4 prefetch + 4 read hits)");
}

/// The ISSUE 5 acceptance grid: `--precision int8,fp8,fp16 --cores 1-9`
/// renders a full 27-cell grid — no unsupported-precision error — and
/// the bytes are identical at `--jobs 1` and `--jobs 8`.
#[test]
fn acceptance_grid_int8_fp8_fp16_full_and_jobs_identical() {
    let base = GridSpec {
        cores: explore::parse_cores("1-9").unwrap(),
        precisions: explore::parse_precisions("int8,fp8,fp16").unwrap(),
        dvfs_steps: 4,
        format: GridFormat::Csv,
    };
    let serial = explore::render(&SweepEngine::new(1), &base);
    let parallel = explore::render(&SweepEngine::new(8), &base);
    assert_eq!(serial, parallel, "--jobs 1 vs --jobs 8 grid diverged");
    assert_eq!(serial.lines().count(), 1 + base.rows());
    // Every core count renders all 4 DVFS rows of its fp8 cell.
    assert_eq!(serial.matches(",fp8,").count(), 9 * 4);
}

/// The widened memos (ISSUE 3): the CWU reference workload and the
/// HD-dimension ablation run once per engine however many times their
/// reports render.
#[test]
fn cwu_and_hd_ablation_memoized_per_engine() {
    let eng = SweepEngine::new(1);
    bench::run_with("table1", &eng).unwrap();
    bench::run_with("table1", &eng).unwrap();
    assert_eq!(eng.cwu_counters(), (1, 1), "second table1 must reuse the CWU training run");

    bench::run_with("ablations", &eng).unwrap();
    bench::run_with("ablations", &eng).unwrap();
    let (hd_hits, hd_misses) = eng.hd_counters();
    assert_eq!(hd_misses, 3, "one HD training per dimension (512/1024/2048)");
    assert_eq!(hd_hits, 3, "second ablation render must reuse all three");
}

/// ISSUE 6 acceptance: a deliberately panicking scenario in the middle
/// of a work list yields exactly one `SimError` cell — carrying its
/// index and panic message — while every other cell completes and
/// matches a fresh fault-free run, at `--jobs 1` and `--jobs 8` alike.
/// A second drain of the same list serves the good cells from the cache
/// (+2 hits) without any re-simulation (+0 misses): the bad scenario
/// panics before it can touch the cache, so it never pollutes it.
#[test]
fn panicking_scenario_isolated_at_jobs_1_and_8() {
    let list = [
        Scenario::IntMatmul { w: IntWidth::I8, cores: 2 },
        Scenario::Nsaa { name: "BOGUS", w: FpWidth::F32 },
        Scenario::Nsaa { name: "FIR", w: FpWidth::F32 },
    ];
    for jobs in [1, 8] {
        let eng = SweepEngine::new(jobs);
        let out = eng.try_run_scenarios(&list);
        assert_eq!(out.len(), 3);

        let err = out[1].as_ref().expect_err("BOGUS cell must error");
        assert_eq!(err.index, 1, "jobs {jobs}: error carries the cell's index");
        assert!(
            err.message.contains("unknown NSAA kernel BOGUS"),
            "jobs {jobs}: panic message surfaced, got: {}",
            err.message
        );

        // The neighbours of the panicking cell match fault-free oracles.
        for i in [0, 2] {
            let got = out[i].as_ref().expect("good cell must complete");
            let oracle = SweepEngine::serial().result(list[i]);
            assert_eq!(got.outputs_digest, oracle.outputs_digest, "jobs {jobs}: cell {i}");
            assert_eq!(got.run.stats, oracle.run.stats, "jobs {jobs}: cell {i}");
        }

        // Second drain: good cells hit the cache, the bad cell re-errors
        // without ever registering as a miss (it panics inside `key()`,
        // before the cache is consulted).
        let (h0, m0) = eng.cache().counters();
        let again = eng.try_run_scenarios(&list);
        assert!(again[1].is_err(), "jobs {jobs}: bad cell errors again");
        assert!(again[0].is_ok() && again[2].is_ok());
        let (h1, m1) = eng.cache().counters();
        assert_eq!(h1 - h0, 2, "jobs {jobs}: both good cells served from cache");
        assert_eq!(m1 - m0, 0, "jobs {jobs}: errored cell never becomes a cache miss");
    }
}

/// The strict path keeps its contract: `run_scenarios` panics with the
/// failing cell's index and message when any cell errors.
#[test]
#[should_panic(expected = "scenario 1: unknown NSAA kernel BOGUS")]
fn strict_run_scenarios_panics_with_cell_index() {
    let list = [
        Scenario::IntMatmul { w: IntWidth::I8, cores: 2 },
        Scenario::Nsaa { name: "BOGUS", w: FpWidth::F32 },
    ];
    let _ = SweepEngine::serial().run_scenarios(&list);
}

/// ISSUE 7: sharded rendering is a partition of the serial grid. Each
/// shard's session renders a subset of the data rows (the cells its
/// FNV-1a slice owns, every DVFS row of each), the shard row sets are
/// pairwise disjoint, and their union is exactly the serial render —
/// the `--jobs` byte-identity invariant extended across processes.
#[test]
fn sharded_renders_partition_the_serial_grid_exactly() {
    let spec = GridSpec {
        cores: (1..=9).collect(),
        precisions: vec![Precision::Int8, Precision::Fp16],
        dvfs_steps: 3,
        format: GridFormat::Csv,
    };
    let serial = explore::render(&SweepEngine::new(1), &spec);
    let all: HashSet<&str> = serial.lines().skip(1).collect();
    assert_eq!(all.len(), spec.rows(), "one distinct data row per grid point");

    let total = 3u32;
    let mut union: HashSet<String> = HashSet::new();
    let mut cells_owned = 0usize;
    for index in 1..=total {
        let session = GridSession::with_shard(ShardSpec { index, total });
        let grid = explore::render_with(&SweepEngine::new(2), &spec, &session);
        let rows: Vec<&str> = grid.text.lines().skip(1).collect();
        assert_eq!(grid.failed, 0, "shard {index}/{total}: no cell may fail");
        assert_eq!(
            rows.len(),
            (18 - grid.skipped) * spec.dvfs_steps,
            "shard {index}/{total}: every owned cell renders all its DVFS rows"
        );
        cells_owned += 18 - grid.skipped;
        for row in rows {
            assert!(all.contains(row), "shard {index}/{total}: foreign row '{row}'");
            assert!(union.insert(row.to_string()), "shard {index}/{total}: duplicate row '{row}'");
        }
    }
    assert_eq!(cells_owned, 18, "the shards own each of the 18 cells exactly once");
    assert_eq!(union.len(), all.len(), "the shard union is the serial grid");
}

/// The cached result is the simulation's result: spot-check one scenario
/// against a direct arena run (stats and output digest).
#[test]
fn cached_results_match_direct_simulation() {
    let s = Scenario::Nsaa { name: "FIR", w: FpWidth::F32 };
    let eng = SweepEngine::new(1);
    let via_engine = eng.result(s);
    let direct = s.simulate(&mut SimArena::new());
    assert_eq!(via_engine.outputs_digest, direct.outputs_digest);
    assert_eq!(via_engine.run.stats, direct.run.stats);
    assert_eq!(via_engine.run.ops, direct.run.ops);
    assert_eq!(via_engine.run.name, direct.run.name);
}
