//! Scheduler-equivalence suite (§Perf): the cycle-skipping fast path in
//! `Cluster::run_program` must be *behaviour-preserving* — bit-identical
//! `ClusterStats` (cycles, every stall counter, conflict rates) and
//! bit-identical functional state (TCDM, L2, register files) versus the
//! retained one-cycle-per-iteration reference loop, across kernels that
//! stress each skip trigger: SIMD matmul (steady-state issue), FFT
//! (barrier parking), a DIV/REM-heavy microkernel (35-cycle busy drains),
//! an FDIV/FSQRT kernel (shared DIV-SQRT unit) and L2-crossing loads
//! (AXI-bridge latency), each at 1, 4 and 8 active cores.

use vega::cluster::{Cluster, SchedulerMode, L2_BASE, TCDM_BASE};
use vega::common::Rng;
use vega::isa::{Asm, Program, Reg, A0, A1, A2, A3, T0, T1, T2};
use vega::iss::FlatMem;
use vega::kernels::int_matmul::{self, IntWidth};
use vega::kernels::{fp_fft, fp_matmul::FpWidth};

const CORE_COUNTS: [usize; 3] = [1, 4, 8];
const MAX_CYCLES: u64 = 50_000_000;

/// Run `prog` on a fresh cluster per scheduler and assert both end in
/// bit-identical state. `setup` seeds TCDM/L2 identically on both sides.
fn assert_prog_equivalent(
    prog: &Program,
    cores: usize,
    setup: impl Fn(&mut Cluster, &mut FlatMem),
    init: impl Fn(usize) -> Vec<(Reg, u32)> + Copy,
    label: &str,
) {
    let mut fast = Cluster::new();
    let mut l2_fast = FlatMem::new(L2_BASE, 64 * 1024);
    setup(&mut fast, &mut l2_fast);
    let stats_fast = fast.run_program(prog, cores, &mut l2_fast, init, MAX_CYCLES);

    let mut refr = Cluster::new();
    refr.scheduler = SchedulerMode::Reference;
    let mut l2_ref = FlatMem::new(L2_BASE, 64 * 1024);
    setup(&mut refr, &mut l2_ref);
    let stats_ref = refr.run_program(prog, cores, &mut l2_ref, init, MAX_CYCLES);

    assert!(stats_fast.cycles > 0, "{label}/c{cores}: empty run");
    assert_eq!(stats_fast, stats_ref, "{label}/c{cores}: stats diverge");
    assert_eq!(
        fast.tcdm.mem.data, refr.tcdm.mem.data,
        "{label}/c{cores}: TCDM contents diverge"
    );
    assert_eq!(l2_fast.data, l2_ref.data, "{label}/c{cores}: L2 contents diverge");
    for (a, b) in fast.cores[..cores].iter().zip(&refr.cores[..cores]) {
        assert_eq!(a.regs, b.regs, "{label}/c{cores}: core {} regfile diverges", a.id);
    }
}

#[test]
fn int_matmul_equivalent_all_widths_and_cores() {
    for w in [IntWidth::I8, IntWidth::I16, IntWidth::I32] {
        for cores in CORE_COUNTS {
            let (m, n, k) = (16, 16, 32);
            let mut rng = Rng::new(0xE9 + cores as u64);
            let lim = if w == IntWidth::I8 { 127 } else { 1000 };
            let av: Vec<i32> =
                (0..m * k).map(|_| rng.range_i64(-lim, lim) as i32).collect();
            let bv: Vec<i32> =
                (0..n * k).map(|_| rng.range_i64(-lim, lim) as i32).collect();

            let mut fast = Cluster::new();
            let mut l2_fast = FlatMem::new(L2_BASE, 4096);
            let (c_fast, run_fast) =
                int_matmul::run(&mut fast, &mut l2_fast, &av, &bv, m, n, k, w, cores);

            let mut refr = Cluster::new();
            refr.scheduler = SchedulerMode::Reference;
            let mut l2_ref = FlatMem::new(L2_BASE, 4096);
            let (c_ref, run_ref) =
                int_matmul::run(&mut refr, &mut l2_ref, &av, &bv, m, n, k, w, cores);

            assert_eq!(c_fast, c_ref, "matmul {w:?}/c{cores}: outputs diverge");
            assert_eq!(
                run_fast.stats, run_ref.stats,
                "matmul {w:?}/c{cores}: stats diverge"
            );
            // And both match the host reference (not just each other).
            assert_eq!(c_fast, int_matmul::host_ref(&av, &bv, m, n, k));
        }
    }
}

#[test]
fn fp_fft_equivalent_across_cores() {
    for cores in CORE_COUNTS {
        let mut rng = Rng::new(77 + cores as u64);
        let x: Vec<(f32, f32)> = (0..128).map(|_| (rng.f32_pm1(), rng.f32_pm1())).collect();

        let mut fast = Cluster::new();
        let (out_fast, run_fast) =
            fp_fft::run(&mut fast, &mut FlatMem::new(L2_BASE, 4096), &x, FpWidth::F32, cores);

        let mut refr = Cluster::new();
        refr.scheduler = SchedulerMode::Reference;
        let (out_ref, run_ref) =
            fp_fft::run(&mut refr, &mut FlatMem::new(L2_BASE, 4096), &x, FpWidth::F32, cores);

        // Bit-exact: both paths executed the same FP ops in the same order.
        let bits = |v: &[(f32, f32)]| -> Vec<(u32, u32)> {
            v.iter().map(|&(r, i)| (r.to_bits(), i.to_bits())).collect()
        };
        assert_eq!(bits(&out_fast), bits(&out_ref), "fft/c{cores}: outputs diverge");
        assert_eq!(run_fast.stats, run_ref.stats, "fft/c{cores}: stats diverge");
        assert!(
            run_fast.stats.barrier_gated_cycles > 0 || cores == 1,
            "fft/c{cores}: expected barrier traffic"
        );
    }
}

#[test]
fn div_heavy_microkernel_equivalent() {
    // 35-cycle serial-divider drains are the biggest single skip window.
    let mut a = Asm::new("div-heavy");
    let end = a.label();
    a.lp_setup_imm(0, 64, end);
    a.div(T0, A0, A1);
    a.rem(T1, A0, A1);
    a.add(A2, A2, T0);
    a.bind(end);
    a.add(A2, A2, T1);
    a.barrier();
    a.div(A3, A2, A1);
    a.halt();
    let prog = a.finish().unwrap();

    for cores in CORE_COUNTS {
        assert_prog_equivalent(
            &prog,
            cores,
            |_, _| {},
            |i| vec![(A0, 10_000 + 37 * i as u32), (A1, 3 + i as u32)],
            "div-heavy",
        );
    }
}

#[test]
fn fdiv_fsqrt_microkernel_equivalent() {
    // The shared DIV-SQRT unit: one op in flight cluster-wide, so cores
    // serialise on it and the busy windows interleave with denials.
    let mut a = Asm::new("fdiv-heavy");
    let end = a.label();
    a.lp_setup_imm(0, 16, end);
    a.fdiv_s(T0, T0, T1);
    a.bind(end);
    a.fsqrt_s(T2, T0);
    a.barrier();
    a.fdiv_s(A2, T2, T1);
    a.halt();
    let prog = a.finish().unwrap();

    for cores in CORE_COUNTS {
        assert_prog_equivalent(
            &prog,
            cores,
            |_, _| {},
            |i| {
                vec![
                    (T0, (1.5f32 + i as f32).to_bits()),
                    (T1, 1.1f32.to_bits()),
                ]
            },
            "fdiv-heavy",
        );
    }
}

#[test]
fn l2_crossing_loads_equivalent() {
    // Cluster-side L2 accesses charge the 8-cycle AXI-bridge latency via
    // add_busy: another skippable stall pattern, plus TCDM copy-back.
    let mut a = Asm::new("l2-stream");
    let end = a.label();
    a.lp_setup_imm(0, 32, end);
    a.lw_pi(T0, A0, 4); // stream from L2
    a.sw_pi(T0, A1, 4); // store to TCDM
    a.bind(end);
    a.barrier();
    a.lw(A2, A0, -4);
    a.halt();
    let prog = a.finish().unwrap();

    for cores in CORE_COUNTS {
        assert_prog_equivalent(
            &prog,
            cores,
            |_, l2| {
                let vals: Vec<i32> = (0..512).map(|v| v * 3 - 700).collect();
                l2.write_i32s(L2_BASE + 0x100, &vals);
            },
            |i| {
                vec![
                    (A0, L2_BASE + 0x100 + 32 * 4 * i as u32),
                    (A1, TCDM_BASE + 32 * 4 * i as u32),
                ]
            },
            "l2-stream",
        );
    }
}

#[test]
fn run_program_reference_entry_point_matches() {
    // The explicit reference entry point behaves like the mode switch.
    let mut a = Asm::new("mini");
    let end = a.label();
    a.lp_setup_imm(0, 10, end);
    a.div(T0, A0, A1);
    a.bind(end);
    a.halt();
    let prog = a.finish().unwrap();

    let init = |_: usize| vec![(A0, 100u32), (A1, 7u32)];
    let mut c1 = Cluster::new();
    let s1 = c1.run_program(&prog, 4, &mut FlatMem::new(L2_BASE, 4096), init, 1_000_000);
    let mut c2 = Cluster::new();
    let s2 =
        c2.run_program_reference(&prog, 4, &mut FlatMem::new(L2_BASE, 4096), init, 1_000_000);
    assert_eq!(s1, s2);
}
