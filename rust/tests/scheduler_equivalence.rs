//! Scheduler-equivalence suite (§Perf): the cycle-skipping fast path in
//! `Cluster::run_program` must be *behaviour-preserving* — bit-identical
//! `ClusterStats` (cycles, every stall counter, conflict rates) and
//! bit-identical functional state (TCDM, L2, register files) versus the
//! retained one-cycle-per-iteration reference loop, across kernels that
//! stress each skip trigger: SIMD matmul (steady-state issue), FFT
//! (barrier parking), a DIV/REM-heavy microkernel (35-cycle busy drains),
//! an FDIV/FSQRT kernel (shared DIV-SQRT unit) and L2-crossing loads
//! (AXI-bridge latency), each at 1, 4 and 8 active cores.
//!
//! The superblock trace-replay tier (`vega::iss::superblock`) rides on
//! the same contract: the `superblock_*` tests below assert bit-identity
//! between replay-on and interpreter-only runs over every
//! `vega verify` target, exercise the adversarial bail paths (trip
//! counts mutated mid-run, pointer-chase bodies that defeat the affine
//! address plan), and reconcile the batched `ClusterStats` against the
//! instruction-by-instruction traced single-core run.

use vega::cluster::{Cluster, SchedulerMode, L2_BASE, TCDM_BASE, TCDM_SIZE};
use vega::common::Rng;
use vega::isa::{Asm, Program, Reg, A0, A1, A2, A3, T0, T1, T2};
use vega::iss::FlatMem;
use vega::kernels::int_matmul::{self, IntWidth};
use vega::kernels::{fp_fft, fp_matmul::FpWidth};

const CORE_COUNTS: [usize; 3] = [1, 4, 8];
const MAX_CYCLES: u64 = 50_000_000;

/// Run `prog` on a fresh cluster per scheduler and assert both end in
/// bit-identical state. `setup` seeds TCDM/L2 identically on both sides.
fn assert_prog_equivalent(
    prog: &Program,
    cores: usize,
    setup: impl Fn(&mut Cluster, &mut FlatMem),
    init: impl Fn(usize) -> Vec<(Reg, u32)> + Copy,
    label: &str,
) {
    let mut fast = Cluster::new();
    let mut l2_fast = FlatMem::new(L2_BASE, 64 * 1024);
    setup(&mut fast, &mut l2_fast);
    let stats_fast = fast.run_program(prog, cores, &mut l2_fast, init, MAX_CYCLES);

    let mut refr = Cluster::new();
    refr.scheduler = SchedulerMode::Reference;
    let mut l2_ref = FlatMem::new(L2_BASE, 64 * 1024);
    setup(&mut refr, &mut l2_ref);
    let stats_ref = refr.run_program(prog, cores, &mut l2_ref, init, MAX_CYCLES);

    assert!(stats_fast.cycles > 0, "{label}/c{cores}: empty run");
    assert_eq!(stats_fast, stats_ref, "{label}/c{cores}: stats diverge");
    assert_eq!(
        fast.tcdm.mem.data, refr.tcdm.mem.data,
        "{label}/c{cores}: TCDM contents diverge"
    );
    assert_eq!(l2_fast.data, l2_ref.data, "{label}/c{cores}: L2 contents diverge");
    for (a, b) in fast.cores[..cores].iter().zip(&refr.cores[..cores]) {
        assert_eq!(a.regs, b.regs, "{label}/c{cores}: core {} regfile diverges", a.id);
    }
}

#[test]
fn int_matmul_equivalent_all_widths_and_cores() {
    for w in [IntWidth::I8, IntWidth::I16, IntWidth::I32] {
        for cores in CORE_COUNTS {
            let (m, n, k) = (16, 16, 32);
            let mut rng = Rng::new(0xE9 + cores as u64);
            let lim = if w == IntWidth::I8 { 127 } else { 1000 };
            let av: Vec<i32> =
                (0..m * k).map(|_| rng.range_i64(-lim, lim) as i32).collect();
            let bv: Vec<i32> =
                (0..n * k).map(|_| rng.range_i64(-lim, lim) as i32).collect();

            let mut fast = Cluster::new();
            let mut l2_fast = FlatMem::new(L2_BASE, 4096);
            let (c_fast, run_fast) =
                int_matmul::run(&mut fast, &mut l2_fast, &av, &bv, m, n, k, w, cores);

            let mut refr = Cluster::new();
            refr.scheduler = SchedulerMode::Reference;
            let mut l2_ref = FlatMem::new(L2_BASE, 4096);
            let (c_ref, run_ref) =
                int_matmul::run(&mut refr, &mut l2_ref, &av, &bv, m, n, k, w, cores);

            assert_eq!(c_fast, c_ref, "matmul {w:?}/c{cores}: outputs diverge");
            assert_eq!(
                run_fast.stats, run_ref.stats,
                "matmul {w:?}/c{cores}: stats diverge"
            );
            // And both match the host reference (not just each other).
            assert_eq!(c_fast, int_matmul::host_ref(&av, &bv, m, n, k));
        }
    }
}

#[test]
fn fp_fft_equivalent_across_cores() {
    for cores in CORE_COUNTS {
        let mut rng = Rng::new(77 + cores as u64);
        let x: Vec<(f32, f32)> = (0..128).map(|_| (rng.f32_pm1(), rng.f32_pm1())).collect();

        let mut fast = Cluster::new();
        let (out_fast, run_fast) =
            fp_fft::run(&mut fast, &mut FlatMem::new(L2_BASE, 4096), &x, FpWidth::F32, cores);

        let mut refr = Cluster::new();
        refr.scheduler = SchedulerMode::Reference;
        let (out_ref, run_ref) =
            fp_fft::run(&mut refr, &mut FlatMem::new(L2_BASE, 4096), &x, FpWidth::F32, cores);

        // Bit-exact: both paths executed the same FP ops in the same order.
        let bits = |v: &[(f32, f32)]| -> Vec<(u32, u32)> {
            v.iter().map(|&(r, i)| (r.to_bits(), i.to_bits())).collect()
        };
        assert_eq!(bits(&out_fast), bits(&out_ref), "fft/c{cores}: outputs diverge");
        assert_eq!(run_fast.stats, run_ref.stats, "fft/c{cores}: stats diverge");
        assert!(
            run_fast.stats.barrier_gated_cycles > 0 || cores == 1,
            "fft/c{cores}: expected barrier traffic"
        );
    }
}

#[test]
fn div_heavy_microkernel_equivalent() {
    // 35-cycle serial-divider drains are the biggest single skip window.
    let mut a = Asm::new("div-heavy");
    let end = a.label();
    a.lp_setup_imm(0, 64, end);
    a.div(T0, A0, A1);
    a.rem(T1, A0, A1);
    a.add(A2, A2, T0);
    a.bind(end);
    a.add(A2, A2, T1);
    a.barrier();
    a.div(A3, A2, A1);
    a.halt();
    let prog = a.finish().unwrap();

    for cores in CORE_COUNTS {
        assert_prog_equivalent(
            &prog,
            cores,
            |_, _| {},
            |i| vec![(A0, 10_000 + 37 * i as u32), (A1, 3 + i as u32)],
            "div-heavy",
        );
    }
}

#[test]
fn fdiv_fsqrt_microkernel_equivalent() {
    // The shared DIV-SQRT unit: one op in flight cluster-wide, so cores
    // serialise on it and the busy windows interleave with denials.
    let mut a = Asm::new("fdiv-heavy");
    let end = a.label();
    a.lp_setup_imm(0, 16, end);
    a.fdiv_s(T0, T0, T1);
    a.bind(end);
    a.fsqrt_s(T2, T0);
    a.barrier();
    a.fdiv_s(A2, T2, T1);
    a.halt();
    let prog = a.finish().unwrap();

    for cores in CORE_COUNTS {
        assert_prog_equivalent(
            &prog,
            cores,
            |_, _| {},
            |i| {
                vec![
                    (T0, (1.5f32 + i as f32).to_bits()),
                    (T1, 1.1f32.to_bits()),
                ]
            },
            "fdiv-heavy",
        );
    }
}

#[test]
fn l2_crossing_loads_equivalent() {
    // Cluster-side L2 accesses charge the 8-cycle AXI-bridge latency via
    // add_busy: another skippable stall pattern, plus TCDM copy-back.
    let mut a = Asm::new("l2-stream");
    let end = a.label();
    a.lp_setup_imm(0, 32, end);
    a.lw_pi(T0, A0, 4); // stream from L2
    a.sw_pi(T0, A1, 4); // store to TCDM
    a.bind(end);
    a.barrier();
    a.lw(A2, A0, -4);
    a.halt();
    let prog = a.finish().unwrap();

    for cores in CORE_COUNTS {
        assert_prog_equivalent(
            &prog,
            cores,
            |_, l2| {
                let vals: Vec<i32> = (0..512).map(|v| v * 3 - 700).collect();
                l2.write_i32s(L2_BASE + 0x100, &vals);
            },
            |i| {
                vec![
                    (A0, L2_BASE + 0x100 + 32 * 4 * i as u32),
                    (A1, TCDM_BASE + 32 * 4 * i as u32),
                ]
            },
            "l2-stream",
        );
    }
}

#[test]
fn run_program_reference_entry_point_matches() {
    // The explicit reference entry point behaves like the mode switch.
    let mut a = Asm::new("mini");
    let end = a.label();
    a.lp_setup_imm(0, 10, end);
    a.div(T0, A0, A1);
    a.bind(end);
    a.halt();
    let prog = a.finish().unwrap();

    let init = |_: usize| vec![(A0, 100u32), (A1, 7u32)];
    let mut c1 = Cluster::new();
    let s1 = c1.run_program(&prog, 4, &mut FlatMem::new(L2_BASE, 4096), init, 1_000_000);
    let mut c2 = Cluster::new();
    let s2 =
        c2.run_program_reference(&prog, 4, &mut FlatMem::new(L2_BASE, 4096), init, 1_000_000);
    assert_eq!(s1, s2);
}

// ---------------------------------------------------------------------------
// Superblock trace replay (vega::iss::superblock)
// ---------------------------------------------------------------------------

/// Run `prog` with the superblock replayer forced on and forced off and
/// assert bit-identical end state. Both runs use the fast scheduler, so
/// any divergence is attributable to the replay tier alone.
fn assert_superblock_equivalent(
    prog: &Program,
    cores: usize,
    setup: impl Fn(&mut Cluster, &mut FlatMem),
    init: impl Fn(usize) -> Vec<(Reg, u32)> + Copy,
    label: &str,
) {
    let run = |sb: bool| {
        let mut cl = Cluster::new();
        cl.superblocks = sb;
        let mut l2 = FlatMem::new(L2_BASE, 64 * 1024);
        setup(&mut cl, &mut l2);
        let stats = cl.run_program(prog, cores, &mut l2, init, MAX_CYCLES);
        (cl, l2, stats)
    };
    let (cl_on, l2_on, stats_on) = run(true);
    let (cl_off, l2_off, stats_off) = run(false);

    assert!(stats_on.cycles > 0, "{label}/c{cores}: empty run");
    assert_eq!(stats_on, stats_off, "{label}/c{cores}: stats diverge");
    assert_eq!(
        cl_on.tcdm.mem.data, cl_off.tcdm.mem.data,
        "{label}/c{cores}: TCDM contents diverge"
    );
    assert_eq!(l2_on.data, l2_off.data, "{label}/c{cores}: L2 contents diverge");
    for (a, b) in cl_on.cores[..cores].iter().zip(&cl_off.cores[..cores]) {
        assert_eq!(a.regs, b.regs, "{label}/c{cores}: core {} regfile diverges", a.id);
    }
}

#[test]
fn superblock_replay_bit_identical_on_all_verify_targets() {
    // Every `vega verify` target — the full shipped kernel surface the
    // static verifier covers — must be bit-identical with replay on vs
    // off, both single-core (replay engages on every hot loop) and at
    // the target's own core count (replay engages during barrier skew).
    for t in vega::sweep::verify_targets() {
        for cores in [1, t.n_cores] {
            assert_superblock_equivalent(
                &t.prog,
                cores,
                |_, _| {},
                |i| t.entry[i].clone(),
                &t.name,
            );
        }
    }
}

#[test]
fn superblock_trip_count_mutation_is_exact() {
    // Adversarial: a Reg-count inner loop whose count register is
    // mutated both *inside* the body and between outer iterations. The
    // hardware snapshots the count at LpSetup time, so each replay must
    // honour the snapshot, never the live register.
    let mut a = Asm::new("trip-mutate");
    let outer = a.label();
    let end = a.label();
    a.bind(outer);
    a.lp_setup(0, T2, end);
    a.lw_pi(T0, A0, 4);
    a.add(A2, A2, T0);
    a.addi(T2, T2, 1); // mutate the count reg mid-body: must not retrip
    a.bind(end);
    a.addi(A3, A3, 1);
    a.addi(T2, T2, 3); // and between setups: next snapshot differs
    a.bne(A3, A1, outer);
    a.barrier();
    a.halt();
    let prog = a.finish().unwrap();

    let init = |i: usize| {
        vec![
            (A0, TCDM_BASE + 0x400 + 0x800 * i as u32),
            (A1, 4u32),
            (T2, 4u32),
        ]
    };
    for cores in [1usize, 4] {
        // Replay-on vs interpreter-only, and fast vs reference.
        assert_superblock_equivalent(&prog, cores, |_, _| {}, init, "trip-mutate");
        assert_prog_equivalent(&prog, cores, |_, _| {}, init, "trip-mutate");
    }
}

#[test]
fn superblock_pointer_chase_bails_to_interpreter() {
    // A load whose base register is its own destination defeats the
    // affine address plan (`SbPlan` is None), so every window entry must
    // bail to the interpreter — and stay bit-identical doing so.
    let mut a = Asm::new("ptr-chase");
    let end = a.label();
    a.lp_setup_imm(0, 16, end);
    a.lw(A0, A0, 0);
    a.addi(A2, A2, 1);
    a.bind(end);
    a.barrier();
    a.halt();
    let prog = a.finish().unwrap();

    let seed = |cl: &mut Cluster, _: &mut FlatMem| {
        // Word-aligned pointer chain inside TCDM (every cell points at
        // another cell; (i*28 + 4) mod 256 keeps 4-byte alignment).
        let vals: Vec<i32> =
            (0..64).map(|i| (TCDM_BASE + (i as u32 * 28 + 4) % 256) as i32).collect();
        cl.tcdm.mem.write_i32s(TCDM_BASE, &vals);
    };
    let init = |_: usize| vec![(A0, TCDM_BASE)];
    for cores in [1usize, 4] {
        assert_superblock_equivalent(&prog, cores, seed, init, "ptr-chase");
        assert_prog_equivalent(&prog, cores, seed, init, "ptr-chase");
    }
}

#[test]
fn superblock_counters_engage_on_hot_loop() {
    // The --stats counters must actually move: a 100-iteration
    // streaming loop on one core replays at least one window covering
    // most iterations. Counters are process-wide monotonic atomics, so
    // under parallel test threads the observed delta can only be >= the
    // contribution of this run.
    let mut a = Asm::new("sb-stream");
    let end = a.label();
    a.lp_setup_imm(0, 100, end);
    a.lw_pi(T0, A0, 4);
    a.add(A2, A2, T0);
    a.bind(end);
    a.halt();
    let prog = a.finish().unwrap();

    let (h0, _, i0) = vega::iss::superblock::counters();
    let mut cl = Cluster::new();
    cl.superblocks = true;
    let mut l2 = FlatMem::new(L2_BASE, 4096);
    let stats =
        cl.run_program(&prog, 1, &mut l2, |_| vec![(A0, TCDM_BASE + 0x100)], MAX_CYCLES);
    let (h1, _, i1) = vega::iss::superblock::counters();

    assert!(stats.cycles > 0);
    assert!(h1 - h0 >= 1, "expected at least one replayed window (got {})", h1 - h0);
    assert!(
        i1 - i0 >= 90,
        "expected >=90 batched iterations from a 100-trip loop (got {})",
        i1 - i0
    );
}

#[test]
fn superblock_stats_reconcile_with_traced_single_core() {
    // Batched ClusterStats must agree *counter by counter* with the
    // instruction-by-instruction traced run: same core model, same
    // TCDM-resident addresses, no barrier (the event unit only exists
    // cluster-side). This pins the per-iteration profile — retires,
    // class counts, ops, bytes, load-use stalls — not just cycles.
    let mut a = Asm::new("sb-reconcile");
    let end = a.label();
    a.lp_setup_imm(0, 64, end);
    a.lw_pi(T0, A0, 4);
    a.mul(T1, T0, T0);
    a.add(A2, A2, T1);
    a.sw_pi(A2, A1, 4);
    a.bind(end);
    a.lw(A3, A0, -4);
    a.halt();
    let prog = a.finish().unwrap();
    let entry = vec![(A0, TCDM_BASE + 0x1000), (A1, TCDM_BASE + 0x2000), (A2, 3u32)];

    let mut cl = Cluster::new();
    cl.superblocks = true;
    let mut l2 = FlatMem::new(L2_BASE, 4096);
    let stats = cl.run_program(&prog, 1, &mut l2, |_| entry.clone(), MAX_CYCLES);

    let mut mem = FlatMem::new(TCDM_BASE, TCDM_SIZE);
    let trace = vega::iss::run_single_traced(&prog, &mut mem, &entry, MAX_CYCLES);

    assert_eq!(
        stats.per_core[0], trace.stats,
        "replayed cluster core stats diverge from the traced single-core run"
    );
}
