//! Lifecycle-engine acceptance (ISSUE 8): determinism, the `.lfc` store
//! tier, the paper's cognitive-sleep regime, and the byte-level goldens
//! that pin the `.lfc` wire format.
//!
//! * the fixed-seed grid renders **byte-identically** at `--jobs 1` and
//!   `--jobs 8` (the crate-wide determinism invariant, extended to the
//!   lifecycle renderer);
//! * a 24 h cognitive trace lands in the paper's 1.7 µW-base power
//!   regime, and every {cognitive, retentive} × {l2, mram} combination
//!   reports a populated battery lifetime and false-wake rate;
//! * the `.lfc` disk tier serves a warm engine entirely from disk, with
//!   exact cold/warm hit/miss/write counters;
//! * golden byte vectors: the 225-byte report encoding against
//!   hand-assembled bit patterns, the versioned cache-key strings
//!   against literal fragments, and the crate's FNV-1a against its
//!   published reference vectors — so the on-disk format can never
//!   drift silently.

use std::fs;
use std::hash::Hasher;
use std::path::{Path, PathBuf};

use vega::common::Fnv1a;
use vega::kernels::int_matmul::IntWidth;
use vega::lifecycle::{
    self, decode_report, encode_report, BootKind, DutyPolicy, LifecycleCmd, LifecycleReport,
    LifecycleScenario, SleepKind, TraceSpec,
};
use vega::sweep::{DiskStore, Scenario, SweepEngine};

fn argv(args: &[&str]) -> Vec<String> {
    args.iter().map(|s| s.to_string()).collect()
}

fn store_dir(case: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("vega-lifecycle-test-{}-{case}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

fn engine_at(dir: &Path, jobs: usize) -> SweepEngine {
    SweepEngine::with_disk(jobs, DiskStore::at(dir).expect("store dir"))
}

/// The fixed-seed acceptance grid: 2 rates × 2 duties × 2 sleeps ×
/// 2 boots = 16 cells over one 600 s trace per rate.
fn acceptance_cmd() -> LifecycleCmd {
    LifecycleCmd::parse(&argv(&[
        "--kernel",
        "matmul-i8",
        "--cores",
        "2",
        "--seed",
        "1",
        "--duration-s",
        "600",
        "--rates",
        "0.05,0.2",
        "--duty",
        "eager,linger",
        "--sleep",
        "cognitive,retentive",
        "--boot",
        "l2,mram",
        "--format",
        "csv",
    ]))
    .unwrap()
}

/// Determinism: the same grid renders byte-identically serial and at
/// `--jobs 8`, and every ok row holds the `true + false == events`
/// invariant the CI smoke greps for.
#[test]
fn grid_renders_byte_identically_at_any_jobs() {
    let cmd = acceptance_cmd();
    let serial = lifecycle::render(&SweepEngine::new(1), &cmd);
    let parallel = lifecycle::render(&SweepEngine::new(8), &cmd);
    assert_eq!(serial, parallel, "lifecycle grid must be --jobs invariant");

    let lines: Vec<&str> = serial.lines().collect();
    assert_eq!(lines.len(), 1 + 16, "header + one row per cell");
    for line in &lines[1..] {
        assert!(line.ends_with(",ok"), "all cells succeed: {line}");
        let f: Vec<&str> = line.split(',').collect();
        let events: u64 = f[7].parse().unwrap();
        let tw: u64 = f[8].parse().unwrap();
        let fw: u64 = f[9].parse().unwrap();
        assert_eq!(tw + fw, events, "every event is exactly one of true/false: {line}");
    }
}

/// The paper regime (§III): a 24 h cognitive-sleep deployment with an
/// MRAM boot image — no retention, the CWU absorbing the false half of
/// a sparse event stream — averages within the 1.7 µW-base envelope,
/// and the battery projection lands where the arithmetic says.
#[test]
fn cognitive_24h_trace_stays_in_the_1_7uw_regime() {
    let eng = SweepEngine::serial();
    let base = LifecycleScenario {
        scenario: Scenario::IntMatmul { w: IntWidth::I8, cores: 8 },
        trace: TraceSpec { seed: 1, duration_s: 86_400.0, rate_hz: 1e-3, true_fraction: 0.5 },
        sleep: SleepKind::Cognitive,
        boot: BootKind::MramRestore,
        duty: DutyPolicy::Eager,
        image_bytes: 256 * 1024,
        battery_mah: 225.0,
        upset_rate: 0.0,
    };
    let r = eng.lifecycle(&base);
    assert!(r.events > 50, "a day at 1 mHz carries ~86 events, got {}", r.events);
    assert!(
        (1.6e-6..=2.5e-6).contains(&r.avg_power_w),
        "24 h cognitive average {} W escaped the 1.7 µW-base regime",
        r.avg_power_w
    );
    assert_eq!(r.absorbed_events, r.false_wakes, "cognitive sleep absorbs every false event");
    assert_eq!(r.boots, r.true_wakes, "and boots only on true ones");
    assert!(r.false_wake_rate > 0.2 && r.false_wake_rate < 0.8, "{}", r.false_wake_rate);
    assert!(r.cwu_accuracy > 0.5, "live CWU summary feeds the report");
    // 225 mAh × 3 V ≈ 0.675 Wh at ~1.7 µW ⇒ a multi-decade projection.
    assert!(
        r.battery_hours > 200_000.0 && r.battery_hours < 600_000.0,
        "battery projection {} h",
        r.battery_hours
    );

    // Every sleep × boot combination reports populated deployment
    // figures (the acceptance matrix).
    for sleep in [SleepKind::Cognitive, SleepKind::Retentive] {
        for boot in [BootKind::WarmL2, BootKind::MramRestore] {
            let r = eng.lifecycle(&LifecycleScenario { sleep, boot, ..base });
            assert!(r.battery_hours > 0.0, "{sleep:?}/{boot:?} lifetime unpopulated");
            assert!(r.avg_power_w > 0.0 && r.total_j > 0.0);
            assert!((0.0..=1.0).contains(&r.false_wake_rate));
            assert_eq!(r.true_wakes + r.false_wakes, r.events);
        }
    }
}

/// The `.lfc` disk tier: a cold engine misses and persists every cell,
/// a warm engine on the same directory serves every report from disk —
/// byte-identical render, exact counters on both sides.
#[test]
fn lfc_tier_cold_then_warm_counters_are_exact() {
    let dir = store_dir("cold-warm");
    let cmd = LifecycleCmd::parse(&argv(&[
        "--kernel",
        "matmul-i8",
        "--cores",
        "2",
        "--seed",
        "3",
        "--duration-s",
        "600",
        "--rates",
        "0.05,0.2",
        "--duty",
        "eager,linger",
        "--sleep",
        "retentive",
        "--boot",
        "l2,mram",
    ]))
    .unwrap();
    let cells = 8u64; // 2 rates x 2 duties x 1 sleep x 2 boots

    let cold = engine_at(&dir, 2);
    let first = lifecycle::render(&cold, &cmd);
    assert_eq!(
        cold.disk_lifecycle_counters(),
        Some((0, cells, cells)),
        "cold: every cell is a disk miss and a write"
    );
    assert_eq!(cold.lifecycle_counters(), (0, cells), "cold memo: one miss per cell");
    let on_disk = fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.path().extension().is_some_and(|x| x == "lfc"))
        .count() as u64;
    assert_eq!(on_disk, cells, "one .lfc entry per cell");

    let warm = engine_at(&dir, 1);
    let second = lifecycle::render(&warm, &cmd);
    assert_eq!(first, second, "warm render must be byte-identical to the cold one");
    assert_eq!(
        warm.disk_lifecycle_counters(),
        Some((cells, 0, 0)),
        "warm: every report served from disk, nothing rewritten"
    );

    // A repeat of a cell on the warm engine is an in-memory hit.
    let _ = warm.lifecycle(&cmd.cells()[0]);
    assert_eq!(warm.lifecycle_counters(), (1, cells));

    let _ = fs::remove_dir_all(&dir);
}

/// Golden bytes (satellite 4): the 225-byte report encoding, assembled
/// by hand from literal little-endian words and IEEE-754 bit patterns —
/// independent of the codec under test. Any change to field order,
/// width or count lands here before it can corrupt a `.lfc` store.
#[test]
fn report_encoding_matches_the_golden_bytes() {
    // Synthetic report: every f64 chosen for a hand-checkable bit
    // pattern (powers of two and short dyadics); energies sum to 7.5.
    let r = LifecycleReport {
        events: 7,
        true_wakes: 4,
        false_wakes: 3,
        absorbed_events: 2,
        boots: 5,
        mram_restores: 5,
        total_s: 86_400.0,
        sleep_s: 600.0,
        classify_s: 3.0,
        wake_s: 2.0,
        triage_s: 1.0,
        infer_s: 0.5,
        sleep_j: 1.0,
        classify_j: 0.75,
        wake_j: 0.5,
        triage_j: 0.25,
        infer_j: 2.0,
        restore_j: 3.0,
        total_j: 7.5,
        avg_power_w: 0.25,
        energy_per_event_j: 0.5,
        false_wake_rate: 0.75,
        battery_hours: 1024.0,
        cwu_accuracy: 0.5,
        mram_flips: 11,
        mram_corrected: 9,
        mram_detected: 2,
        mram_silent: 0,
        diverged: true,
    };
    let mut want = Vec::with_capacity(225);
    for v in [7u64, 4, 3, 2, 5, 5] {
        want.extend_from_slice(&v.to_le_bytes());
    }
    for bits in [
        0x40F5_1800_0000_0000_u64, // 86400.0  total_s
        0x4082_C000_0000_0000,     // 600.0    sleep_s
        0x4008_0000_0000_0000,     // 3.0      classify_s
        0x4000_0000_0000_0000,     // 2.0      wake_s
        0x3FF0_0000_0000_0000,     // 1.0      triage_s
        0x3FE0_0000_0000_0000,     // 0.5      infer_s
        0x3FF0_0000_0000_0000,     // 1.0      sleep_j
        0x3FE8_0000_0000_0000,     // 0.75     classify_j
        0x3FE0_0000_0000_0000,     // 0.5      wake_j
        0x3FD0_0000_0000_0000,     // 0.25     triage_j
        0x4000_0000_0000_0000,     // 2.0      infer_j
        0x4008_0000_0000_0000,     // 3.0      restore_j
        0x401E_0000_0000_0000,     // 7.5      total_j
        0x3FD0_0000_0000_0000,     // 0.25     avg_power_w
        0x3FE0_0000_0000_0000,     // 0.5      energy_per_event_j
        0x3FE8_0000_0000_0000,     // 0.75     false_wake_rate
        0x4090_0000_0000_0000,     // 1024.0   battery_hours
        0x3FE0_0000_0000_0000,     // 0.5      cwu_accuracy
    ] {
        want.extend_from_slice(&bits.to_le_bytes());
    }
    for v in [11u64, 9, 2, 0] {
        want.extend_from_slice(&v.to_le_bytes());
    }
    want.push(1); // diverged = true
    assert_eq!(want.len(), 225, "6 + 18 + 4 words x 8 bytes, + 1 bool byte");

    let got = encode_report(&r);
    assert_eq!(got, want, "encoding drifted from the golden bytes");
    assert_eq!(decode_report(&want), Some(r), "golden bytes decode to the source report");

    // The digest is FNV-1a over exactly these bytes.
    let mut h = Fnv1a::new();
    h.write(&want);
    assert_eq!(r.digest(), h.finish());
}

/// Golden key strings: the trace fragment and the scenario key rendered
/// against hard-coded literals (seed hex, `to_bits` hex of every f64,
/// the versioned prefix, and every axis label). The cache key IS the
/// disk format's identity — pin it character-for-character.
#[test]
fn cache_keys_match_their_golden_strings() {
    let trace = TraceSpec { seed: 1, duration_s: 86_400.0, rate_hz: 0.5, true_fraction: 0.5 };
    assert_eq!(
        trace.key_fragment(),
        "seed=0000000000000001|dur=40f5180000000000|rate=3fe0000000000000|tp=3fe0000000000000"
    );

    let lc = LifecycleScenario {
        scenario: Scenario::IntMatmul { w: IntWidth::I8, cores: 8 },
        trace,
        sleep: SleepKind::Cognitive,
        boot: BootKind::WarmL2,
        duty: DutyPolicy::Eager,
        image_bytes: 256 * 1024,
        battery_mah: 225.0,
        upset_rate: 0.0,
    };
    let k = lc.key();
    assert!(k.starts_with("lifecycle-v1|matmul_i8|"), "versioned prefix + kernel id: {k}");
    assert!(
        k.contains("|seed=0000000000000001|dur=40f5180000000000|rate=3fe0000000000000|tp=3fe0000000000000|"),
        "trace fragment embedded verbatim: {k}"
    );
    assert!(
        k.ends_with(
            "|sleep=cognitive|boot=l2|duty=eager|img=262144|mah=406c200000000000|ur=0000000000000000"
        ),
        "axis suffix: {k}"
    );
}

/// The crate's single pinned hash, against the published FNV-1a 64-bit
/// reference vectors — the anchor under every store path name, journal
/// key and report digest.
#[test]
fn fnv1a_matches_the_published_reference_vectors() {
    for (input, want) in [
        ("", 0xcbf2_9ce4_8422_2325_u64),
        ("a", 0xaf63_dc4c_8601_ec8c),
        ("foobar", 0x8594_4171_f739_67e8),
    ] {
        let mut h = Fnv1a::new();
        h.write(input.as_bytes());
        assert_eq!(h.finish(), want, "FNV-1a(\"{input}\")");
    }
}
