//! Cluster-level integration: emergent microarchitectural properties the
//! paper claims (contention, sharing, scaling), checked across kernels.

use vega::cluster::{Cluster, L2_BASE};
use vega::common::Rng;
use vega::iss::FlatMem;
use vega::kernels::fp_matmul::{self, FpWidth};
use vega::kernels::int_matmul::{self, IntWidth};

fn l2() -> FlatMem {
    FlatMem::new(L2_BASE, 64 * 1024)
}

/// "The cluster L1 memory can serve 16 parallel memory requests with less
/// than 10% contention rate even on data-intensive kernels" (§II-C).
#[test]
fn tcdm_contention_below_10pct_on_matmul() {
    let mut rng = Rng::new(1);
    let av: Vec<i32> = (0..64 * 64).map(|_| rng.range_i64(-128, 127) as i32).collect();
    let bv: Vec<i32> = (0..64 * 64).map(|_| rng.range_i64(-128, 127) as i32).collect();
    let mut cl = Cluster::new();
    let (_, kr) =
        int_matmul::run(&mut cl, &mut l2(), &av, &bv, 64, 64, 64, IntWidth::I8, 8);
    assert!(
        kr.stats.tcdm_conflict_rate < 0.10,
        "conflict rate = {}",
        kr.stats.tcdm_conflict_rate
    );
}

/// "The design choice of exploiting shared FPUs is not detrimental to the
/// performance of FP workloads" (§IV-A): 8 cores on 4 FPUs must retain
/// ≥70% of the ideal 2× scaling from 4 cores (which have private FPUs).
#[test]
fn fpu_sharing_not_detrimental() {
    let mut rng = Rng::new(2);
    let (m, n, k) = (32, 32, 32);
    let av: Vec<f32> = (0..m * k).map(|_| rng.f32_pm1()).collect();
    let bv: Vec<f32> = (0..n * k).map(|_| rng.f32_pm1()).collect();
    let mut cl = Cluster::new();
    let (_, k4) = fp_matmul::run(&mut cl, &mut l2(), &av, &bv, m, n, k, FpWidth::F32, 4);
    let mut cl = Cluster::new();
    let (_, k8) = fp_matmul::run(&mut cl, &mut l2(), &av, &bv, m, n, k, FpWidth::F32, 8);
    let scaling = k4.stats.cycles as f64 / k8.stats.cycles as f64;
    assert!(scaling > 1.4, "4->8 core scaling = {scaling} (ideal 2.0)");
}

/// Near-linear parallel speedup for the integer path (private-ish FPU-free
/// datapaths): 1→8 cores ≥ 6.5×.
#[test]
fn int_matmul_scales_nearly_linearly() {
    let mut rng = Rng::new(3);
    let av: Vec<i32> = (0..32 * 32).map(|_| rng.range_i64(-128, 127) as i32).collect();
    let bv: Vec<i32> = (0..32 * 32).map(|_| rng.range_i64(-128, 127) as i32).collect();
    let mut cycles = Vec::new();
    for cores in [1usize, 2, 4, 8] {
        let mut cl = Cluster::new();
        let (_, kr) =
            int_matmul::run(&mut cl, &mut l2(), &av, &bv, 32, 32, 32, IntWidth::I8, cores);
        cycles.push(kr.stats.cycles as f64);
    }
    let s8 = cycles[0] / cycles[3];
    assert!(s8 > 6.5, "1->8 speedup = {s8}");
    // Monotone scaling.
    assert!(cycles[0] > cycles[1] && cycles[1] > cycles[2] && cycles[2] > cycles[3]);
}

/// Results are identical no matter how many cores run the kernel (the
/// SPMD decomposition is purely spatial).
#[test]
fn results_independent_of_core_count() {
    let mut rng = Rng::new(4);
    let av: Vec<i32> = (0..16 * 32).map(|_| rng.range_i64(-128, 127) as i32).collect();
    let bv: Vec<i32> = (0..16 * 32).map(|_| rng.range_i64(-128, 127) as i32).collect();
    let mut base = None;
    for cores in [1usize, 3, 5, 8] {
        let mut cl = Cluster::new();
        let (c, _) =
            int_matmul::run(&mut cl, &mut l2(), &av, &bv, 16, 16, 32, IntWidth::I8, cores);
        match &base {
            None => base = Some(c),
            Some(b) => assert_eq!(&c, b, "{cores} cores"),
        }
    }
}

/// int8 : int16 : int32 throughput follows SIMD lane counts (Fig. 6's
/// format scaling).
#[test]
fn simd_format_scaling() {
    let mut rng = Rng::new(5);
    let av: Vec<i32> = (0..32 * 32).map(|_| rng.range_i64(-100, 100) as i32).collect();
    let bv: Vec<i32> = (0..32 * 32).map(|_| rng.range_i64(-100, 100) as i32).collect();
    let rate = |w: IntWidth| {
        let mut cl = Cluster::new();
        let (_, kr) = int_matmul::run(&mut cl, &mut l2(), &av, &bv, 32, 32, 32, w, 8);
        kr.stats.mac_per_cycle()
    };
    let (r8, r16, r32) = (rate(IntWidth::I8), rate(IntWidth::I16), rate(IntWidth::I32));
    assert!(r8 > 1.6 * r16, "8 vs 16: {r8} / {r16}");
    assert!(r16 > 1.7 * r32, "16 vs 32: {r16} / {r32}");
}
