//! The headline-number anchors (DESIGN.md §4): every claim the abstract
//! makes must *emerge* from the simulator + calibrated power model within
//! tolerance. These are the reproduction's acceptance tests.

use std::sync::OnceLock;

use vega::common::rel_err;
use vega::coordinator;
use vega::dnn::{self, repvgg, run_network, PipelineConfig, StorePolicy, Variant};
use vega::kernels::fp_matmul::FpWidth;
use vega::kernels::int_matmul::IntWidth;
use vega::power::{self, tables as pt};
use vega::sweep::{Scenario, SweepEngine};

/// File-local **in-memory** engine: the anchor suite is the regression
/// oracle, so it must always exercise the live simulator. The per-id
/// `coordinator::bench_*` paths route through the *persistent*
/// `SweepEngine::global()`, where a stale on-disk entry (e.g. a
/// timing-model change that forgot its `MODEL_EPOCH` bump) could satisfy
/// these asserts with pre-change cycle counts.
fn oracle() -> &'static SweepEngine {
    static ENG: OnceLock<SweepEngine> = OnceLock::new();
    ENG.get_or_init(SweepEngine::default)
}

/// "614 GOPS/W on 8-bit INT computation" (abstract, Table VIII) and
/// "15.6 GOPS" peak.
#[test]
fn int8_perf_and_efficiency() {
    let kr = oracle().kernel_run(Scenario::IntMatmul { w: IntWidth::I8, cores: 8 });
    let (gops_hv, _) = coordinator::efficiency(&kr, power::HV, 0.0);
    assert!(rel_err(gops_hv, 15.6) < 0.15, "peak int8 = {gops_hv} GOPS");
    let (gops_lv, eff_lv) = coordinator::efficiency(&kr, power::LV, 0.0);
    assert!(rel_err(eff_lv, 614.0) < 0.15, "int8 eff = {eff_lv} GOPS/W");
    assert!(rel_err(gops_lv, 7.6) < 0.15, "int8 LV = {gops_lv} GOPS");
}

/// "79 and 129 GFLOPS/W on 32- and 16-bit FP" (abstract); 2 / 3.3 GFLOPS
/// peaks (Table VIII).
#[test]
fn fp_perf_and_efficiency() {
    let f32_run = oracle().kernel_run(Scenario::FpMatmul { w: FpWidth::F32, cores: 8 });
    let (gflops, _) = coordinator::efficiency(&f32_run, power::HV, 0.0);
    assert!(rel_err(gflops, 2.0) < 0.35, "fp32 = {gflops} GFLOPS");
    let (_, eff32) = coordinator::efficiency(&f32_run, power::LV, 0.0);
    assert!(rel_err(eff32, 79.0) < 0.35, "fp32 eff = {eff32} GFLOPS/W");

    let f16_run = oracle().kernel_run(Scenario::FpMatmul { w: FpWidth::F16x2, cores: 8 });
    let (gflops16, _) = coordinator::efficiency(&f16_run, power::HV, 0.0);
    // Our hand-scheduled vfdotpex kernel avoids overheads the measured
    // library paid, so the simulated fp16 point *exceeds* the paper's
    // 3.3 GFLOPS (documented in EXPERIMENTS.md); the anchor is a band.
    assert!((3.0..6.5).contains(&gflops16), "fp16 = {gflops16} GFLOPS");
    let (_, eff16) = coordinator::efficiency(&f16_run, power::LV, 0.0);
    assert!(eff16 > 110.0 && eff16 < 280.0, "fp16 eff = {eff16} GFLOPS/W");
    // FP16 must beat FP32 on both axes.
    assert!(gflops16 > gflops && eff16 > eff32);
}

/// "32.2 GOPS (@ 49.4 mW) peak performance" with the HWCE active.
#[test]
fn peak_ml_with_hwce() {
    let net = repvgg(Variant::A0);
    let hy = run_network(
        &net,
        dnn::PipelineConfig {
            op: power::HV,
            engine: dnn::Engine::HwceHybrid,
            policy: StorePolicy::GreedyMram,
        },
    );
    let gops = hy.mac_per_cycle() * 2.0 * power::HV.f_cl / 1e9;
    assert!(rel_err(gops, 32.2) < 0.20, "peak ML = {gops} GOPS");
    let p = power::cluster_power_w(power::HV, 1.0, 1.0) + power::soc_power_w(power::HV, 0.3);
    assert!(p < 49.4e-3 * 1.10, "power envelope = {} mW", p * 1e3);
}

/// "1.7 µW fully retentive cognitive sleep mode" + Table I totals.
#[test]
fn cwu_power_anchors() {
    let run = coordinator::cwu_reference_run(32_000.0);
    let duty = run.duty_at_150sps;
    let p_sleep = power::cwu_power_w(32e3, duty, false);
    assert!(rel_err(p_sleep, 1.7e-6) < 0.10, "cognitive sleep = {p_sleep} W");
    let p_total = power::cwu_power_w(32e3, duty, true);
    assert!(rel_err(p_total, 2.97e-6) < 0.10, "CWU total = {p_total} W");
    assert!(run.accuracy > 0.85, "wake-up accuracy = {}", run.accuracy);
}

/// MobileNetV2: "1.19 mJ/inference" on MRAM, 3.5× over HyperRAM, >10 fps.
#[test]
fn mobilenet_anchors() {
    let net = dnn::mobilenet_v2();
    let m = run_network(&net, PipelineConfig::nominal_sw(StorePolicy::AllMram));
    let h = run_network(&net, PipelineConfig::nominal_sw(StorePolicy::AllHyperRam));
    assert!(rel_err(m.energy_mj(), 1.19) < 0.25, "MRAM = {} mJ", m.energy_mj());
    assert!(rel_err(h.energy_mj(), 4.16) < 0.25, "Hyper = {} mJ", h.energy_mj());
    assert!(m.fps() > 10.0, "fps = {}", m.fps());
}

/// RepVGG-A family, Table VII: ~3× HWCE speedup, 60–95% efficiency gain,
/// latency ordering A0 < A1 < A2.
#[test]
fn repvgg_table7_anchors() {
    let paper_sw_ms = [358.0, 610.0, 1320.0];
    let mut last = 0.0;
    for (v, sw_ms) in [Variant::A0, Variant::A1, Variant::A2].iter().zip(paper_sw_ms) {
        let net = repvgg(*v);
        let sw = run_network(&net, PipelineConfig::nominal_sw(StorePolicy::GreedyMram));
        let hw = run_network(&net, PipelineConfig::table7_hwce(StorePolicy::GreedyMram));
        assert!(
            rel_err(sw.latency_s() * 1e3, sw_ms) < 0.20,
            "{v:?} SW = {} ms (paper {sw_ms})",
            sw.latency_s() * 1e3
        );
        let speedup = sw.latency_s() / hw.latency_s();
        assert!((2.2..3.6).contains(&speedup), "{v:?} speedup = {speedup}");
        assert!(hw.energy_mj() < sw.energy_mj(), "{v:?} energy");
        assert!(sw.latency_s() > last, "latency ordering");
        last = sw.latency_s();
    }
}

/// Retention power range: "2.8 – 123.7 µW (16 kB – 1.6 MB s.r.)".
#[test]
fn retention_anchors() {
    let lo = power::PowerMode::CognitiveSleep { retentive_l2_bytes: 16 * 1024 }.power_w();
    let hi = power::PowerMode::CognitiveSleep { retentive_l2_bytes: 1600 * 1024 }.power_w();
    assert!(rel_err(lo, 2.8e-6) < 0.10, "lo = {lo}");
    assert!(rel_err(hi, 123.7e-6) < 0.10, "hi = {hi}");
}

/// Fig. 8's suite-average FP16 vectorization speedup ≈ 1.46×.
#[test]
fn fp16_vectorization_average() {
    let mut sum = 0.0;
    for name in coordinator::NSAA_KERNELS {
        let k32 = oracle().kernel_run(Scenario::Nsaa { name, w: FpWidth::F32 });
        let k16 = oracle().kernel_run(Scenario::Nsaa { name, w: FpWidth::F16x2 });
        // Normalise per unit of work (some drivers use different sizes).
        let t32 = k32.stats.cycles as f64 / k32.ops as f64;
        let t16 = k16.stats.cycles as f64 / k16.ops as f64;
        sum += t32 / t16;
    }
    let avg = sum / coordinator::NSAA_KERNELS.len() as f64;
    assert!((1.2..2.2).contains(&avg), "avg f16 speedup = {avg} (paper 1.46)");
}

/// FC active mode: ≈200 GOPS/W int8 at up to 1.9 GOPS (§III).
#[test]
fn fc_active_mode() {
    let kr = oracle().kernel_run(Scenario::IntMatmul { w: IntWidth::I8, cores: 1 });
    let gops = kr.gops_at(pt::HV.f_soc);
    assert!((1.0..2.5).contains(&gops), "FC int8 = {gops} GOPS");
}
