//! Static-verifier suite (ISSUE 9): golden diagnostics on seeded defect
//! programs, clean passes over every shipped kernel, and the
//! static-vs-dynamic oracle.
//!
//! The oracle is the load-bearing layer: for each canonical kernel
//! program it runs the instrumented single-core ISS
//! ([`vega::iss::run_single_traced`]) under the exact entry-register
//! state `vega verify` analyzes, then checks that every fact the
//! analyzer claimed to prove holds on the live machine —
//!
//! * dynamically issued pcs ⊆ statically reachable pcs,
//! * dynamically written registers ⊆ the may-def mask,
//! * every statically resolved memory access (constant address, size,
//!   direction) is exactly what the traced run observed at that pc,
//! * traced per-pc byte totals reconcile with the core's own counters.

use vega::cluster::{TCDM_BASE, TCDM_SIZE};
use vega::isa::analyze::{self, FindingKind, Severity};
use vega::isa::{Asm, A0, A1, T0, T1};
use vega::iss::{run_single_traced, FlatMem};
use vega::kernels::VerifyTarget;
use vega::sweep::verify_targets;

const MAX_CYCLES: u64 = 200_000_000;

// ---------------------------------------------------------------------
// Golden diagnostics: each seeded defect class must produce its
// error-severity finding (and therefore a non-zero `vega verify` exit).
// ---------------------------------------------------------------------

#[test]
fn golden_uninitialized_register_read() {
    let mut a = Asm::new("defect_uninit");
    a.add(T0, A0, A1); // A0 and A1 were never written
    a.halt();
    let p = a.finish().unwrap();
    let r = analyze::analyze(&p, &[]);
    assert!(r.has_error(FindingKind::UninitRead), "report:\n{}", r.render());
    assert!(r.error_count() >= 2, "both source registers flagged:\n{}", r.render());

    // The same program is clean once the entry state defines the inputs.
    let r = analyze::analyze(&p, &[(A0, 1), (A1, 2)]);
    assert!(!r.has_error(FindingKind::UninitRead), "report:\n{}", r.render());
}

#[test]
fn golden_tcdm_out_of_bounds() {
    let mut a = Asm::new("defect_oob");
    a.li(A0, (TCDM_BASE + TCDM_SIZE as u32) as i32); // one past the end
    a.lw(T0, A0, 0);
    a.halt();
    let p = a.finish().unwrap();
    let r = analyze::analyze(&p, &[]);
    assert!(r.has_error(FindingKind::OutOfBounds), "report:\n{}", r.render());
}

#[test]
fn golden_misaligned_word_load() {
    let mut a = Asm::new("defect_misaligned");
    a.li(A0, (TCDM_BASE + 2) as i32);
    a.lw(T0, A0, 0); // word load on a halfword boundary
    a.halt();
    let p = a.finish().unwrap();
    let r = analyze::analyze(&p, &[]);
    assert!(r.has_error(FindingKind::Misaligned), "report:\n{}", r.render());

    // A halfword load at the same address is legal.
    let mut a = Asm::new("ok_halfword");
    a.li(A0, (TCDM_BASE + 2) as i32);
    a.lh(T0, A0, 0);
    a.halt();
    let p = a.finish().unwrap();
    let r = analyze::analyze(&p, &[]);
    assert!(!r.has_error(FindingKind::Misaligned), "report:\n{}", r.render());
}

#[test]
fn golden_unreachable_block() {
    let mut a = Asm::new("defect_unreachable");
    let end = a.label();
    a.j(end);
    a.li(A0, 1); // jumped over, no path in
    a.bind(end);
    a.halt();
    let p = a.finish().unwrap();
    let r = analyze::analyze(&p, &[]);
    assert!(r.has_error(FindingKind::UnreachableBlock), "report:\n{}", r.render());
    assert!(!r.reachable_pcs[1]);
}

#[test]
fn golden_dead_store() {
    let mut a = Asm::new("defect_dead_store");
    a.li(A0, TCDM_BASE as i32);
    a.li(T0, 1);
    a.li(T1, 2);
    a.sw(T0, A0, 0); // overwritten below, never read in between
    a.sw(T1, A0, 0);
    a.halt();
    let p = a.finish().unwrap();
    let r = analyze::analyze(&p, &[]);
    assert!(r.has_error(FindingKind::DeadStore), "report:\n{}", r.render());
    let f = r.findings.iter().find(|f| f.kind == FindingKind::DeadStore).unwrap();
    assert_eq!(f.pc, Some(3), "the *earlier* store is the dead one");
}

// ---------------------------------------------------------------------
// Clean pass: every shipped kernel at every precision, every core's
// entry state — zero error-severity findings (the `vega verify all`
// CI gate in library form).
// ---------------------------------------------------------------------

#[test]
fn all_shipped_kernels_verify_clean() {
    let targets = verify_targets();
    assert!(targets.len() >= 20, "canonical suite shrank to {}", targets.len());
    for t in &targets {
        for core in 0..t.n_cores {
            let r = t.analyze_core(core);
            assert_eq!(
                r.error_count(),
                0,
                "{} core {core} has error findings:\n{}",
                t.name,
                r.render()
            );
            // Kernel programs are fully reachable and loop-shaped.
            assert!(r.reachable_pcs.iter().all(|&x| x), "{}: unreachable code", t.name);
            assert!(r.n_loops >= 1, "{}: no loops found", t.name);
        }
    }
}

#[test]
fn kernels_yield_superblock_candidates() {
    // The CFG/loop output feeds the ROADMAP superblock item: the suite
    // must surface straight-line hardware-loop bodies as candidates.
    let targets = verify_targets();
    let with_candidates = targets
        .iter()
        .filter(|t| {
            t.analyze_core(0)
                .findings
                .iter()
                .any(|f| f.kind == FindingKind::SuperblockCandidate)
        })
        .count();
    assert!(with_candidates >= 10, "only {with_candidates} targets have candidates");
}

// ---------------------------------------------------------------------
// Static-vs-dynamic oracle.
// ---------------------------------------------------------------------

/// Trace `target`'s program on one core over zeroed TCDM and check every
/// static claim against the observed execution.
fn check_oracle(t: &VerifyTarget, core: usize) {
    let report = t.analyze_core(core);
    let mut mem = FlatMem::new(TCDM_BASE, TCDM_SIZE);
    let trace = run_single_traced(&t.prog, &mut mem, &t.entry[core], MAX_CYCLES);
    let label = format!("{} core {core}", t.name);

    // 1. Issued pcs ⊆ reachable pcs.
    for pc in 0..t.prog.insts.len() {
        assert!(
            !trace.executed[pc] || report.reachable_pcs[pc],
            "{label}: pc {pc} issued but statically unreachable"
        );
    }

    // 2. Written registers ⊆ may-def mask.
    let escaped = trace.regs_written & !report.may_def_mask;
    assert_eq!(escaped, 0, "{label}: registers {escaped:#010x} written outside may-def mask");

    // 3. Every resolved access is exactly what the machine did.
    for (pc, fact) in report.resolved_mem.iter().enumerate() {
        let (Some(f), Some(touch)) = (fact, &trace.mem[pc]) else { continue };
        assert_eq!(
            touch.uniform,
            Some(f.addr),
            "{label}: pc {pc} resolved to {:#010x} but ran at {:#010x}..{:#010x}",
            f.addr,
            touch.min_addr,
            touch.max_addr
        );
        assert_eq!(touch.write, f.write, "{label}: pc {pc} direction mismatch");
        assert_eq!(
            touch.bytes,
            touch.count * u64::from(f.bytes),
            "{label}: pc {pc} element size mismatch"
        );
    }

    // 4. Trace byte totals reconcile with the core's own counters.
    let (loaded, stored) = trace.touched_bytes();
    assert_eq!(loaded, trace.stats.bytes_loaded, "{label}: loaded-byte reconciliation");
    assert_eq!(stored, trace.stats.bytes_stored, "{label}: stored-byte reconciliation");
}

#[test]
fn oracle_holds_for_every_canonical_kernel() {
    // First and last core bracket the entry-state range (base pointers
    // at both ends of each chunked allocation).
    for t in &verify_targets() {
        check_oracle(t, 0);
        if t.n_cores > 1 {
            check_oracle(t, t.n_cores - 1);
        }
    }
}

#[test]
fn analyzer_findings_are_severity_typed() {
    // Spot-check the report surface the CLI renders: severities order,
    // names are stable, and rendering never panics.
    let targets = verify_targets();
    let r = targets[0].analyze_core(0);
    for w in r.findings.windows(2) {
        assert!(w[0].severity >= w[1].severity, "report not sorted");
    }
    for f in &r.findings {
        assert!(!f.kind.name().is_empty());
        assert!(f.severity <= Severity::Error);
        let _ = f.to_string();
    }
    let _ = r.render();
}
