//! Persistent on-disk SimCache invariants (ISSUE 3 acceptance):
//!
//! * a simulation written by one engine is served from disk to a later
//!   engine on the same directory (the cross-process sharing story —
//!   each engine here stands in for a process, which is exactly what it
//!   is to the store: a cold in-memory cache over a shared directory);
//! * the acceptance grid (`--cores 1..9 --precision int8,fp16`) renders
//!   byte-identically on a second invocation with **every** simulation
//!   served from disk (hit counts asserted);
//! * corrupted, truncated or version-mismatched entries are misses that
//!   fall back to re-simulation — never wrong data, never a panic;
//! * a seeded single-byte corruption fuzzer (ISSUE 6) sweeps every frame
//!   region of the `.sim`, `.net` and `.lfc` tiers: every mutation reads
//!   back as a miss, every restore as a hit, with exact per-region and
//!   per-tier counts;
//! * (ISSUE 7) a store write that cannot land warns once, counts in
//!   `disk_write_errors`, and the engine continues in memory with
//!   correct results.

use std::fs;
use std::path::PathBuf;

use vega::common::Rng;
use vega::dnn::{net_key, Layer, LayerKind, Network, PipelineConfig, StorePolicy};
use vega::kernels::int_matmul::IntWidth;
use vega::lifecycle::{BootKind, DutyPolicy, LifecycleScenario, SleepKind, TraceSpec};
use vega::sweep::explore::{self, GridFormat, GridSpec, Precision};
use vega::sweep::{DiskStore, Scenario, SweepEngine};

/// Fresh per-test store directory (unique per process and case; removed
/// at entry so a leftover from a crashed run can't pollute counters).
fn store_dir(case: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("vega-disk-cache-test-{}-{case}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

fn engine_at(dir: &PathBuf, jobs: usize) -> SweepEngine {
    SweepEngine::with_disk(jobs, DiskStore::at(dir).expect("store dir"))
}

/// The single entry file with extension `ext` in a store directory.
fn entry_with_ext(dir: &PathBuf, ext: &str) -> PathBuf {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == ext))
        .collect();
    assert_eq!(entries.len(), 1, "expected exactly one .{ext} entry in {dir:?}");
    entries.pop().unwrap()
}

/// The single `.sim` entry file of a store directory.
fn only_entry(dir: &PathBuf) -> PathBuf {
    entry_with_ext(dir, "sim")
}

#[test]
fn results_round_trip_across_engines() {
    let dir = store_dir("roundtrip");
    let s = Scenario::IntMatmul { w: IntWidth::I8, cores: 2 };

    let cold = engine_at(&dir, 1);
    let first = cold.result(s);
    assert_eq!(cold.disk_counters(), Some((0, 1, 1)), "cold: one disk miss, one write");

    let warm = engine_at(&dir, 1);
    let second = warm.result(s);
    assert_eq!(warm.disk_counters(), Some((1, 0, 0)), "warm: served from disk, no write");
    assert_eq!(first.outputs_digest, second.outputs_digest);
    assert_eq!(first.run.stats, second.run.stats);
    assert_eq!(first.run.ops, second.run.ops);
    assert_eq!(first.run.name, second.run.name);

    // And the disk result equals a from-scratch simulation (purity).
    let fresh = SweepEngine::serial().result(s);
    assert_eq!(second.outputs_digest, fresh.outputs_digest);
    assert_eq!(second.run.stats, fresh.run.stats);

    let _ = fs::remove_dir_all(&dir);
}

/// The acceptance grid: cores 1..9 × {int8, fp16} renders a table not in
/// the paper, byte-identical across jobs, and a second invocation of the
/// same grid serves every simulation from the on-disk cache.
#[test]
fn acceptance_grid_warm_starts_entirely_from_disk() {
    let dir = store_dir("acceptance");
    let spec = GridSpec {
        cores: (1..=9).collect(),
        precisions: vec![Precision::Int8, Precision::Fp16],
        dvfs_steps: 4,
        format: GridFormat::Csv,
    };
    let cells = (spec.cores.len() * spec.precisions.len()) as u64;

    let cold = engine_at(&dir, 4);
    let first = explore::render(&cold, &spec);
    assert_eq!(first.lines().count(), 1 + spec.rows(), "header + one row per grid point");
    let (_, dm, dw) = cold.disk_counters().unwrap();
    assert_eq!((dm, dw), (cells, cells), "cold run simulates and persists every cell");

    let warm = engine_at(&dir, 1);
    let second = explore::render(&warm, &spec);
    assert_eq!(first, second, "warm render must be byte-identical to the cold one");
    assert_eq!(
        warm.disk_counters(),
        Some((cells, 0, 0)),
        "second invocation serves every simulation from the on-disk cache"
    );

    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn version_mismatch_falls_back_to_resimulation() {
    let dir = store_dir("version");
    let s = Scenario::IntMatmul { w: IntWidth::I8, cores: 3 };
    let baseline = engine_at(&dir, 1).result(s);

    // Flip a byte of the version field (offset 8, right after the magic).
    let path = only_entry(&dir);
    let mut bytes = fs::read(&path).unwrap();
    bytes[8] ^= 0xFF;
    fs::write(&path, &bytes).unwrap();

    let eng = engine_at(&dir, 1);
    let recovered = eng.result(s);
    assert_eq!(eng.disk_counters(), Some((0, 1, 1)), "mismatch = miss + fresh write-back");
    assert_eq!(recovered.outputs_digest, baseline.outputs_digest);
    assert_eq!(recovered.run.stats, baseline.run.stats);

    // The rewritten entry is valid again.
    let healed = engine_at(&dir, 1);
    healed.result(s);
    assert_eq!(healed.disk_counters(), Some((1, 0, 0)));

    let _ = fs::remove_dir_all(&dir);
}

/// ISSUE 6 satellite: the point corruption tests above, generalized into
/// a seeded fuzzer. For each of the six frame regions — magic, version,
/// epoch, key echo, payload (with its length prefix), checksum — apply
/// four deterministic single-byte XOR mutations (offsets and values from
/// the repo's own seeded [`Rng`]), on a `.sim`, a `.net` and a `.lfc`
/// entry. Every mutated entry must read back as a miss (never wrong
/// data, never a panic), every restored entry as a hit, with exact
/// per-region and per-tier counts.
#[test]
fn seeded_fuzzer_every_single_byte_mutation_reads_as_a_miss() {
    let dir = store_dir("fuzz");
    let s = Scenario::IntMatmul { w: IntWidth::I8, cores: 2 };
    let net = Network {
        name: "fuzz-net".into(),
        layers: vec![Layer {
            name: "c".into(),
            kind: LayerKind::Conv { k: 3, stride: 2, cin: 3, cout: 8 },
            in_h: 16,
            in_w: 16,
        }],
    };
    let cfg = PipelineConfig::nominal_sw(StorePolicy::AllMram);

    // One entry per tier, written through a persistent engine. The
    // lifecycle scenario reuses `s` as its true-event workload, so its
    // inference is a memo hit and the only new entry is the `.lfc` one.
    let lc = LifecycleScenario {
        scenario: s,
        trace: TraceSpec { seed: 2, duration_s: 60.0, rate_hz: 0.1, true_fraction: 0.5 },
        sleep: SleepKind::Retentive,
        boot: BootKind::MramRestore,
        duty: DutyPolicy::Eager,
        image_bytes: 64 * 1024,
        battery_mah: 225.0,
        upset_rate: 0.0,
    };
    let writer = engine_at(&dir, 1);
    let _ = writer.result(s);
    let _ = writer.network_report(&net, cfg);
    let _ = writer.lifecycle(&lc);
    let sim_key = s.key();
    let report_key = net_key(&net, &cfg);
    let lfc_key = lc.key();

    let store = DiskStore::at(&dir).expect("store dir");
    let mut rng = Rng::new(0xF022);
    let mut mutations = 0u32;
    for ext in ["sim", "net", "lfc"] {
        let path = entry_with_ext(&dir, ext);
        let good = fs::read(&path).unwrap();
        let key_len = u32::from_le_bytes(good[16..20].try_into().unwrap()) as usize;
        let regions: [(usize, usize, &str); 6] = [
            (0, 8, "magic"),
            (8, 12, "version"),
            (12, 16, "epoch"),
            (16, 20 + key_len, "key"),
            (20 + key_len, good.len() - 8, "payload"),
            (good.len() - 8, good.len(), "checksum"),
        ];
        for (start, end, what) in regions {
            let mut region_misses = 0u32;
            for _ in 0..4 {
                let off = start + rng.below((end - start) as u64) as usize;
                let xor = 1 + rng.below(255) as u8;
                let mut bad = good.clone();
                bad[off] ^= xor;
                fs::write(&path, &bad).unwrap();
                let miss = match ext {
                    "sim" => store.load(&sim_key).is_none(),
                    "net" => store.load_net(&report_key).is_none(),
                    _ => store.load_lifecycle(&lfc_key).is_none(),
                };
                assert!(miss, ".{ext}/{what}: byte {off} ^ {xor:#04x} must read as a miss");
                region_misses += 1;
                fs::write(&path, &good).unwrap();
                let hit = match ext {
                    "sim" => store.load(&sim_key).is_some(),
                    "net" => store.load_net(&report_key).is_some(),
                    _ => store.load_lifecycle(&lfc_key).is_some(),
                };
                assert!(hit, ".{ext}/{what}: restored entry must read back as a hit");
            }
            assert_eq!(region_misses, 4, ".{ext}/{what}: exactly four mutations");
            mutations += region_misses;
        }
    }
    assert_eq!(mutations, 72, "6 regions x 4 mutations x 3 tiers");
    assert_eq!(store.counters(), (24, 24, 0), "sim tier: one hit + one miss per mutation");
    assert_eq!(store.net_counters(), (24, 24, 0), "net tier: one hit + one miss per mutation");
    assert_eq!(
        store.lifecycle_counters(),
        (24, 24, 0),
        "lfc tier: one hit + one miss per mutation"
    );

    let _ = fs::remove_dir_all(&dir);
}

/// ISSUE 7 satellite: a store write that cannot land degrades to
/// continue-in-memory with the damage counted, never a panic and never
/// a wrong result. The entry path is replaced by a *directory*, so the
/// tmp-file rename fails under any uid (a read-only permission bit
/// would be bypassed by root, which CI containers often run as).
#[test]
fn failed_entry_writes_are_counted_and_never_change_results() {
    let dir = store_dir("write-error");
    let s = Scenario::IntMatmul { w: IntWidth::I8, cores: 4 };
    let baseline = engine_at(&dir, 1).result(s);

    // Wedge the entry's destination: rename(tmp, dir) cannot succeed.
    let path = only_entry(&dir);
    fs::remove_file(&path).unwrap();
    fs::create_dir(&path).unwrap();

    let eng = engine_at(&dir, 1);
    let recovered = eng.result(s);
    assert_eq!(recovered.outputs_digest, baseline.outputs_digest, "the result is unharmed");
    assert_eq!(recovered.run.stats, baseline.run.stats);
    assert_eq!(
        eng.disk_counters(),
        Some((0, 1, 0)),
        "the unreadable entry is a miss and the failed write never counts as a write"
    );
    assert_eq!(
        eng.disk_write_errors(),
        Some((1, 0, 0, 0)),
        "the failed sim-tier write is counted for --stats"
    );

    // The same engine keeps serving from memory afterwards.
    let again = eng.result(s);
    assert_eq!(again.outputs_digest, baseline.outputs_digest);
    assert_eq!(eng.disk_write_errors(), Some((1, 0, 0, 0)), "a memo hit retries nothing");

    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn truncated_and_garbage_entries_fall_back_to_resimulation() {
    let dir = store_dir("truncated");
    let s = Scenario::IntMatmul { w: IntWidth::I16, cores: 2 };
    let baseline = engine_at(&dir, 1).result(s);

    let path = only_entry(&dir);
    let bytes = fs::read(&path).unwrap();
    fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    let eng = engine_at(&dir, 1);
    let recovered = eng.result(s);
    assert_eq!(eng.disk_counters(), Some((0, 1, 1)), "truncated entry is a miss");
    assert_eq!(recovered.outputs_digest, baseline.outputs_digest);

    fs::write(&path, b"not a cache entry at all").unwrap();
    let eng = engine_at(&dir, 1);
    eng.result(s);
    assert_eq!(eng.disk_counters(), Some((0, 1, 1)), "garbage entry is a miss");

    let _ = fs::remove_dir_all(&dir);
}
