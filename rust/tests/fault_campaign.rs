//! Fault-campaign invariants (ISSUE 6 acceptance):
//!
//! * a fixed-seed campaign grid is **byte-replayable**: identical
//!   outcomes and identical rendered report bytes at `--jobs 1` and
//!   `--jobs 8`;
//! * the per-tier classification counters are exactly derivable from
//!   the plan's own flip expansion — corrected equals the single-bit
//!   words, detected at least the double-bit words, masked exactly the
//!   net-cancelled words, and **zero silent corruptions** on the
//!   SECDED-protected MRAM tier unless a word took ≥3 effective flips;
//! * a campaign whose MRAM upsets are all single-bit reads back the
//!   exact staged image: no divergence from the fault-free oracle;
//! * the unprotected TCDM tier turns the same class of upsets into
//!   silent data corruption — the contrast the ECC-coverage report is
//!   built to show;
//! * campaign outcomes persist through the on-disk `.flt` tier: a cold
//!   engine writes them, a fresh engine on the same directory replays
//!   them from disk, bit-identical.

use std::collections::HashMap;
use std::fs;
use std::path::PathBuf;

use vega::faults::{cli, Campaign, FaultPlan, FaultsCmd, FlipList, Tier, TierFaults, TierMask};
use vega::kernels::int_matmul::IntWidth;
use vega::sweep::{DiskStore, Scenario, SweepEngine};

fn argv(args: &[&str]) -> Vec<String> {
    args.iter().map(|s| s.to_string()).collect()
}

/// Net effective flip count per storage unit: every unit any flip
/// landed in, mapped to the number of its bits flipped an odd number of
/// times (even multiplicities cancel in silicon and in the model).
fn effective_flips(list: &FlipList) -> HashMap<usize, usize> {
    let mut parity: HashMap<(usize, u32), usize> = HashMap::new();
    for f in &list.flips {
        *parity.entry((f.unit, f.bit)).or_insert(0) += 1;
    }
    let mut per_unit: HashMap<usize, usize> = HashMap::new();
    for f in &list.flips {
        per_unit.entry(f.unit).or_insert(0);
    }
    for ((unit, _), n) in parity {
        if n % 2 == 1 {
            *per_unit.entry(unit).or_insert(0) += 1;
        }
    }
    per_unit
}

/// MRAM-only plan at (seed, rate) for the cheap 2-core int8 matmul.
fn mram_campaign(seed: u64, rate: f64) -> Campaign {
    Campaign {
        scenario: Scenario::IntMatmul { w: IntWidth::I8, cores: 2 },
        plan: FaultPlan {
            seed,
            sleep_s: 3600.0,
            mram_rate: rate,
            sram_rate: rate,
            tiers: TierMask { mram: true, l2: false, tcdm: false },
        },
    }
}

/// Deterministic search over a (rate, seed) ladder for the first
/// campaign whose single flip list satisfies `want` — robust to the
/// staged image size without baking in golden flip counts.
fn find_campaign(
    rates: &[f64],
    build: impl Fn(u64, f64) -> Campaign,
    want: impl Fn(&FlipList) -> bool,
) -> Campaign {
    for &rate in rates {
        for seed in 1..=32u64 {
            let c = build(seed, rate);
            let lists = c.flip_lists();
            assert_eq!(lists.len(), 1, "single-tier plan expands to one list");
            if want(&lists[0]) {
                return c;
            }
        }
    }
    panic!("no (seed, rate) in the ladder satisfied the campaign predicate");
}

/// The acceptance invocation: a fixed-seed campaign grid replays
/// byte-identically at `--jobs 1` and `--jobs 8` — both the raw
/// outcomes and the rendered CSV report.
#[test]
fn campaign_grid_byte_replayable_across_jobs() {
    let cmd = FaultsCmd::parse(&argv(&[
        "--kernel", "matmul-f32", "--cores", "8", "--seeds", "7,8", "--rates", "1e-5,2e-4",
        "--tiers", "mram", "--sleep-s", "3600", "--format", "csv",
    ]))
    .unwrap();
    let grid = cmd.campaigns();
    let eng1 = SweepEngine::new(1);
    let eng8 = SweepEngine::new(8);
    let serial: Vec<_> = eng1.run_campaigns(&grid).into_iter().map(|r| r.unwrap()).collect();
    let parallel: Vec<_> = eng8.run_campaigns(&grid).into_iter().map(|r| r.unwrap()).collect();
    assert_eq!(serial, parallel, "outcomes diverged between --jobs 1 and --jobs 8");
    assert_eq!(
        cli::render(&eng1, &cmd),
        cli::render(&eng8, &cmd),
        "rendered report bytes diverged between --jobs 1 and --jobs 8"
    );
}

/// The classifier's counters are a pure function of the expansion: for
/// every upset MRAM word, its net effective flip count decides the
/// SECDED outcome — 0 masked, 1 corrected, 2 detected, and only ≥3 can
/// escape. The test derives those counts from `flip_lists()` and holds
/// the campaign to them exactly.
#[test]
fn mram_classification_matches_the_expansion_exactly() {
    let c = find_campaign(&[1e-6, 1e-5, 1e-4], mram_campaign, |l| l.flips.len() >= 5);
    let lists = c.flip_lists();
    assert_eq!(lists[0].tier, Tier::Mram);
    let per_unit = effective_flips(&lists[0]);
    let count = |n: usize| per_unit.values().filter(|&&v| v == n).count() as u64;
    let (w0, w1, w2) = (count(0), count(1), count(2));
    let w3 = per_unit.values().filter(|&&v| v >= 3).count() as u64;

    let out = SweepEngine::serial().run_campaigns(&[c]).pop().unwrap().unwrap();
    let m = &out.stats.mram;
    assert_eq!(m.flips, lists[0].flips.len() as u64);
    assert_eq!(m.words, per_unit.len() as u64, "every upset word classified once");
    assert_eq!(m.masked, w0, "masked = words whose flips net-cancelled");
    assert_eq!(m.corrected, w1, "corrected = exactly the single-bit words");
    assert!(m.detected >= w2, "every double-bit word is detected");
    assert!(m.silent <= w3, "silent corruption requires >=3 effective flips");
    assert_eq!(m.detected + m.silent, w2 + w3);
    if w3 == 0 {
        assert_eq!(m.silent, 0, "zero silent corruptions under a <=2-bit campaign");
    }
    // Untargeted tiers stay untouched.
    assert_eq!(out.stats.l2, TierFaults::default());
    assert_eq!(out.stats.tcdm, TierFaults::default());
}

/// Full ECC coverage: when every upset MRAM word took at most one
/// effective flip, the architectural read-back reconstructs the staged
/// image exactly — nothing detected, nothing poisoned, nothing silent,
/// and the faulted run's outputs match the fault-free oracle's.
#[test]
fn all_single_bit_mram_upsets_correct_fully_and_never_diverge() {
    let c = find_campaign(&[1e-6, 1e-5], mram_campaign, |l| {
        !l.flips.is_empty() && effective_flips(l).values().all(|&n| n <= 1)
    });
    let out = SweepEngine::serial().run_campaigns(&[c]).pop().unwrap().unwrap();
    let m = &out.stats.mram;
    assert!(m.words > 0);
    assert_eq!(m.corrected + m.masked, m.words, "every word corrected or net-cancelled");
    assert_eq!(m.detected, 0);
    assert_eq!(m.silent, 0);
    assert_eq!(out.poisoned_words, 0, "no uncorrectable words under single-bit upsets");
    assert_eq!(out.ecc.detected, 0, "the controller saw nothing uncorrectable either");
    assert!(out.ecc.corrected >= m.corrected, "read-back corrected every single-bit word");
    assert!(!out.diverged, "full correction implies a bit-true kernel run");
    assert_eq!(out.faulted_digest, out.oracle_digest);
}

/// The contrast the report exists to show: the same class of upsets on
/// the unprotected TCDM has no ECC to hide behind — every word whose
/// flips did not net-cancel is silent data corruption.
#[test]
fn unprotected_tcdm_upsets_become_silent_data_corruption() {
    let tcdm_campaign = |seed, rate| Campaign {
        plan: FaultPlan {
            tiers: TierMask { mram: false, l2: false, tcdm: true },
            ..mram_campaign(seed, rate).plan
        },
        ..mram_campaign(seed, rate)
    };
    // SRAM rates are per active run (no sleep scaling), so landing a
    // handful of flips in a tens-of-kB image needs rates in whole
    // upsets per Mbit — far above any realistic soft-error rate, which
    // is exactly the point of an accelerated injection campaign.
    let c = find_campaign(&[4.0, 40.0], tcdm_campaign, |l| {
        effective_flips(l).values().any(|&n| n >= 1)
    });
    let lists = c.flip_lists();
    let per_unit = effective_flips(&lists[0]);
    let flipped = per_unit.values().filter(|&&n| n >= 1).count() as u64;
    let cancelled = per_unit.values().filter(|&&n| n == 0).count() as u64;

    let out = SweepEngine::serial().run_campaigns(&[c]).pop().unwrap().unwrap();
    let t = &out.stats.tcdm;
    assert!(t.silent >= 1, "an unprotected tier cannot hide a net flip");
    assert_eq!(t.silent, flipped, "every net-flipped byte is silent corruption");
    assert_eq!(t.masked, cancelled, "net-cancelled bytes read back intact");
    assert_eq!(t.corrected, 0, "no ECC on TCDM: nothing can be corrected");
    assert_eq!(t.detected, 0, "no ECC on TCDM: nothing can be detected");
    assert_eq!(out.stats.mram, TierFaults::default());
    assert_eq!(out.ecc.corrected + out.ecc.detected, 0);
    assert_eq!(out.poisoned_words, 0);
}

/// Campaign outcomes round-trip through the persistent `.flt` store
/// tier: a cold engine runs and writes, a fresh engine on the same
/// directory serves every outcome from disk, bit-identical, and its
/// in-memory memo takes over on the second drain.
#[test]
fn flt_tier_cold_then_warm_round_trips_outcomes() {
    let dir: PathBuf = std::env::temp_dir()
        .join(format!("vega-fault-campaign-test-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    let grid = [mram_campaign(1, 1e-4), mram_campaign(2, 1e-4)];

    let cold = SweepEngine::with_disk(1, DiskStore::at(&dir).expect("store dir"));
    let first: Vec<_> = cold.run_campaigns(&grid).into_iter().map(|r| r.unwrap()).collect();
    assert_eq!(cold.fault_counters(), (0, 2), "cold: both campaigns are memo misses");
    assert_eq!(
        cold.disk_fault_counters(),
        Some((0, 2, 2)),
        "cold: both campaigns miss the .flt tier and are written back"
    );
    let flt_files = fs::read_dir(&dir)
        .unwrap()
        .filter(|e| e.as_ref().unwrap().path().extension().is_some_and(|x| x == "flt"))
        .count();
    assert_eq!(flt_files, 2, "one .flt entry per campaign");

    let warm = SweepEngine::with_disk(1, DiskStore::at(&dir).expect("store dir"));
    let second: Vec<_> = warm.run_campaigns(&grid).into_iter().map(|r| r.unwrap()).collect();
    assert_eq!(first, second, "disk-served outcomes must be bit-identical");
    assert_eq!(
        warm.disk_fault_counters(),
        Some((2, 0, 0)),
        "warm: every outcome served from the .flt tier, nothing rewritten"
    );

    let third: Vec<_> = warm.run_campaigns(&grid).into_iter().map(|r| r.unwrap()).collect();
    assert_eq!(second, third);
    assert_eq!(warm.fault_counters(), (2, 2), "second drain hits the in-memory memo");
    assert_eq!(warm.disk_fault_counters(), Some((2, 0, 0)), "memo hits never re-probe disk");

    let _ = fs::remove_dir_all(&dir);
}
