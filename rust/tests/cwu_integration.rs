//! CWU end-to-end integration: sensors → SPI → preprocessor → Hypnos →
//! PMU wake-up, on both paper workloads (EMG gestures, language id), plus
//! the error-resilience property HDC's always-on role depends on.

use vega::common::Rng;
use vega::cwu::hypnos::HdVec;
use vega::cwu::{ChannelConfig, Cwu};
use vega::hdc::{self, datasets, EncoderConfig};
use vega::mem::Mram;
use vega::power::{self, pmu::BootPath, PowerMode, WakeSource};

fn emg_cfg() -> EncoderConfig {
    EncoderConfig {
        dim: 2048,
        input_width: 16,
        cim_max: 4095,
        channels: 3,
        window: 16,
        ngram: 1,
        discrete: false,
    }
}

#[test]
fn emg_wakeup_accuracy_and_pmu_handoff() {
    let cfg = emg_cfg();
    let mut gen = datasets::EmgGenerator::new(42);
    let model = hdc::train(cfg, &gen.dataset(5, cfg.window));
    let hypnos = model.program_hypnos(1, (cfg.dim / 4) as u16);
    let mut cwu = Cwu::with_config(
        None,
        &[ChannelConfig { in_width: 16, ..Default::default() }; 3],
        hypnos,
        32_000.0,
    );

    let mut pmu = power::Pmu::new();
    pmu.enter(PowerMode::CognitiveSleep { retentive_l2_bytes: 128 * 1024 });
    let mram = Mram::new();

    let mut true_pos = 0;
    let mut false_pos = 0;
    for class in 0..gen.n_classes() {
        for _ in 0..15 {
            let w = gen.window(class, cfg.window);
            let woke = w.iter().any(|f| cwu.step_with_raw(f).is_some());
            if woke && class == 1 {
                true_pos += 1;
            }
            if woke && class != 1 {
                false_pos += 1;
            }
        }
    }
    assert!(true_pos >= 13, "true positives {true_pos}/15");
    assert!(false_pos <= 2, "false positives {false_pos}/45");

    // A wake event drives the PMU out of cognitive sleep.
    let latency = pmu
        .wake(
            WakeSource::Cognitive,
            1.0,
            power::NOM,
            BootPath::WarmFromL2,
            &mram,
        )
        .expect("wake from cognitive sleep");
    assert!(latency < 1e-4, "warm-boot latency = {latency}");
    assert!(matches!(pmu.mode, PowerMode::SocActive { .. }));
}

#[test]
fn language_identification_with_trigrams() {
    let cfg = EncoderConfig {
        dim: 2048,
        input_width: 5,
        cim_max: 26,
        channels: 1,
        window: 64,
        ngram: 3,
        discrete: true,
    };
    let mut gen = datasets::LangGenerator::new(7, 3);
    let model = hdc::train(cfg, &gen.dataset(6, cfg.window));
    let mut correct = 0;
    let mut total = 0;
    for class in 0..gen.n_classes() {
        for _ in 0..15 {
            if model.classify(&gen.window(class, cfg.window)) == class {
                correct += 1;
            }
            total += 1;
        }
    }
    let acc = correct as f64 / total as f64;
    assert!(acc > 0.85, "language id accuracy = {acc}");
}

/// "Inherent error-resiliency in the presence of random bit flips"
/// (§II-B [22]): classification survives corrupted prototypes.
#[test]
fn hdc_resilient_to_prototype_bit_flips() {
    let cfg = emg_cfg();
    let mut gen = datasets::EmgGenerator::new(11);
    let mut model = hdc::train(cfg, &gen.dataset(5, cfg.window));

    // Flip 5% of every prototype's bits (e.g. MRAM retention upsets that
    // slipped past ECC, or low-voltage AM failures).
    let mut rng = Rng::new(13);
    let flips = cfg.dim / 20;
    let protos: Vec<HdVec> = model
        .prototypes
        .iter()
        .map(|p| {
            let mut q = p.clone();
            for _ in 0..flips {
                q.flip(rng.below(cfg.dim as u64) as usize);
            }
            q
        })
        .collect();
    model.prototypes = protos;

    let mut correct = 0;
    let mut total = 0;
    for class in 0..gen.n_classes() {
        for _ in 0..10 {
            if model.classify(&gen.window(class, cfg.window)) == class {
                correct += 1;
            }
            total += 1;
        }
    }
    let acc = correct as f64 / total as f64;
    assert!(acc > 0.8, "accuracy under 5% bit flips = {acc}");
}

/// The CWU's false-positive discipline is what makes duty-cycling pay
/// (§II-B): average system power with cognitive wake-up must undercut a
/// threshold wake-up that fires 20× more often.
#[test]
fn cognitive_wakeup_saves_system_power() {
    let active = PowerMode::ClusterActive {
        op: power::NOM,
        fc_util: 0.5,
        core_util: 1.0,
        hwce_active: 0.0,
    };
    let sleep_hdc = PowerMode::CognitiveSleep { retentive_l2_bytes: 128 * 1024 };
    let sleep_thr = PowerMode::RetentiveSleep { retentive_l2_bytes: 128 * 1024 };
    // 1 true event/hour, 100 ms of active processing per wake.
    // HDC: ~1 false positive per true event. Threshold: ~20.
    let p_hdc = power::Pmu::duty_cycled_power_w(active, sleep_hdc, 2.0 * 0.1, 3600.0).unwrap();
    let p_thr = power::Pmu::duty_cycled_power_w(active, sleep_thr, 21.0 * 0.1, 3600.0).unwrap();
    assert!(p_hdc < p_thr, "hdc {p_hdc} vs threshold {p_thr}");
    assert!(p_hdc < 50e-6, "average power = {p_hdc}");
}

/// Preprocessor + Hypnos sample-rate budget at 32 kHz (Table I).
#[test]
fn sample_rate_budget_at_32khz() {
    let cfg = emg_cfg();
    let mut gen = datasets::EmgGenerator::new(21);
    let model = hdc::train(cfg, &gen.dataset(3, cfg.window));
    let hypnos = model.program_hypnos(1, 400);
    let mut cwu = Cwu::with_config(
        None,
        &[ChannelConfig { in_width: 16, ..Default::default() }; 3],
        hypnos,
        32_000.0,
    );
    for _ in 0..5 {
        let w = gen.window(1, cfg.window);
        for f in &w {
            cwu.step_with_raw(f);
        }
    }
    // At 150 SPS/channel the datapath must have headroom (duty < 1).
    let duty = cwu.datapath_duty(150.0);
    assert!(duty < 0.5, "duty = {duty}");
    assert!(cwu.max_sample_rate() > 150.0);
}
