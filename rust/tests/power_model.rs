//! Paper anchors for the sleep/wake power model (§II-A, §III, Fig. 7,
//! Tables I/III/VIII) — the foundation the lifecycle engine integrates
//! over. Each test pins one identity the lifecycle reports depend on:
//! the 1.7 µW cognitive-sleep base, the 1.2 µW deep-sleep floor, the
//! 16 kB retention-cut ladder, the duty-cycle lifetime equation's
//! endpoints, and the wake-latency decomposition (domain switch + MRAM
//! restore). If one of these drifts, every battery-lifetime number in
//! `tests/lifecycle.rs` drifts with it — anchor here, at the source.

use vega::common::rel_err;
use vega::cwu::SLEEP_CLK_HZ;
use vega::mem::{BulkChannel, Mram};
use vega::power::tables::{
    CWU_REF_DUTY, DEEP_SLEEP_W, RETENTION_FIRST_CUT_W, RETENTION_PER_CUT_W,
};
use vega::power::{
    cwu_power_w, retention_power_w, BootPath, LifecycleError, Pmu, PowerMode, WakeSource, HV, NOM,
};
use vega::soc::l2::RETENTION_CUT_BYTES;

/// §III: "1.7 µW cognitive sleep" — the CognitiveSleep base power (no
/// retained L2) is the CWU at its 32 kHz sleep clock and reference duty,
/// pads excluded, and it lands in the paper's quoted regime.
#[test]
fn cognitive_sleep_base_is_the_paper_1_7_uw() {
    let base = PowerMode::CognitiveSleep { retentive_l2_bytes: 0 }.power_w();
    assert_eq!(base, cwu_power_w(SLEEP_CLK_HZ, CWU_REF_DUTY, false));
    assert!((1.6e-6..=1.8e-6).contains(&base), "base = {base}");

    // Table I cross-check: folding the SPI pads back in at 32 kHz gives
    // the measured 2.97 µW total.
    let with_pads = cwu_power_w(SLEEP_CLK_HZ, CWU_REF_DUTY, true);
    assert!(rel_err(with_pads, 2.97e-6) < 0.01, "with pads = {with_pads}");

    // The datapath term scales with measured duty but saturates at 3× the
    // reference workload (the model's stated clamp): any duty past the
    // clamp yields the identical power.
    let saturated = cwu_power_w(SLEEP_CLK_HZ, CWU_REF_DUTY * 10.0, false);
    assert_eq!(saturated, cwu_power_w(SLEEP_CLK_HZ, 1.0, false));
    assert!(saturated > base);
}

/// Table III floor: deep sleep is exactly the calibrated 1.2 µW constant
/// (PMU + RTC + POR), nothing else.
#[test]
fn deep_sleep_is_the_1_2_uw_floor() {
    assert_eq!(PowerMode::DeepSleep.power_w(), DEEP_SLEEP_W);
    assert_eq!(DEEP_SLEEP_W, 1.2e-6);
}

/// Table VIII: L2 retention is paid per 16 kB cut — zero bytes cost
/// nothing, one byte costs a whole first cut, and each further started
/// cut adds the per-cut increment.
#[test]
fn retention_tracks_the_16_kb_cut_ladder() {
    assert_eq!(retention_power_w(0), 0.0);
    assert_eq!(retention_power_w(1), RETENTION_FIRST_CUT_W);
    assert_eq!(retention_power_w(RETENTION_CUT_BYTES), RETENTION_FIRST_CUT_W);
    assert_eq!(
        retention_power_w(RETENTION_CUT_BYTES + 1),
        RETENTION_FIRST_CUT_W + RETENTION_PER_CUT_W
    );
    // 1.6 MB = 100 cuts.
    assert_eq!(
        retention_power_w(100 * RETENTION_CUT_BYTES),
        RETENTION_FIRST_CUT_W + 99.0 * RETENTION_PER_CUT_W
    );

    // Table VIII quotes the cognitive + retention range "2.8–123.7 µW
    // (16 kB–1.6 MB s.r.)" — both endpoints must emerge.
    let lo = PowerMode::CognitiveSleep { retentive_l2_bytes: RETENTION_CUT_BYTES }.power_w();
    let hi = PowerMode::CognitiveSleep { retentive_l2_bytes: 100 * RETENTION_CUT_BYTES }.power_w();
    assert!(rel_err(lo, 2.8e-6) < 0.01, "16 kB endpoint = {lo}");
    assert!(rel_err(hi, 123.7e-6) < 0.01, "1.6 MB endpoint = {hi}");
}

/// Fig. 7: retentive sleep (no CWU) is the deep-sleep floor plus the
/// retention ladder — an exact identity at every image size.
#[test]
fn retentive_sleep_is_deep_sleep_plus_retention() {
    for bytes in [0, RETENTION_CUT_BYTES, 256 * 1024, 100 * RETENTION_CUT_BYTES] {
        assert_eq!(
            PowerMode::RetentiveSleep { retentive_l2_bytes: bytes }.power_w(),
            DEEP_SLEEP_W + retention_power_w(bytes),
            "bytes = {bytes}"
        );
    }
}

/// §II-B's lifetime equation: the duty-cycled average interpolates
/// linearly between the sleep power (active_s = 0) and the active power
/// (active_s = period_s), and is monotone in the active time between.
#[test]
fn duty_cycle_endpoints_and_monotonicity() {
    let active = PowerMode::SocActive { op: NOM, fc_util: 1.0 };
    let sleep = PowerMode::CognitiveSleep { retentive_l2_bytes: 0 };
    let period = 600.0;

    let idle = Pmu::duty_cycled_power_w(active, sleep, 0.0, period).unwrap();
    assert!(rel_err(idle, sleep.power_w()) < 1e-12, "idle = {idle}");
    let busy = Pmu::duty_cycled_power_w(active, sleep, period, period).unwrap();
    assert!(rel_err(busy, active.power_w()) < 1e-12, "busy = {busy}");

    let mut last = idle;
    for active_s in [1e-3, 10e-3, 1.0, 60.0, 599.0] {
        let p = Pmu::duty_cycled_power_w(active, sleep, active_s, period).unwrap();
        assert!(p > last, "not monotone at active_s = {active_s}");
        last = p;
    }
    assert!(last < busy);
}

/// §III wake-up: latency decomposes exactly into the 2000-cycle domain
/// switch plus (for the MRAM path) the timed image restore — the same
/// two terms the lifecycle engine charges per boot.
#[test]
fn wake_latency_decomposes_into_switch_plus_restore() {
    let mram = Mram::new();

    let mut pmu = Pmu::new();
    pmu.enter(PowerMode::RetentiveSleep { retentive_l2_bytes: 256 * 1024 });
    let t_l2 = pmu.wake(WakeSource::Rtc, 0.0, NOM, BootPath::WarmFromL2, &mram).unwrap();
    assert_eq!(t_l2, 2_000.0 / NOM.f_soc);
    assert_eq!(pmu.mode, PowerMode::SocActive { op: NOM, fc_util: 0.5 });

    let image_bytes = 256 * 1024;
    let mut pmu = Pmu::new();
    pmu.enter(PowerMode::CognitiveSleep { retentive_l2_bytes: 0 });
    let t_mram = pmu
        .wake(WakeSource::Cognitive, 0.0, NOM, BootPath::WarmFromMram { image_bytes }, &mram)
        .unwrap();
    let restore = mram.transfer_cycles(image_bytes, NOM.f_soc, false) as f64 / NOM.f_soc;
    assert_eq!(t_mram, 2_000.0 / NOM.f_soc + restore);
    // 256 kB at the Table VI 300 MB/s sustained rate ≈ 0.87 ms.
    assert!(rel_err(restore, 256.0 * 1024.0 / 300e6) < 0.05, "restore = {restore}");

    // The switch term scales with f_soc: HV boots faster than NOM.
    let mut pmu = Pmu::new();
    pmu.enter(PowerMode::DeepSleep);
    let t_hv = pmu.wake(WakeSource::ExternalPad, 0.0, HV, BootPath::WarmFromL2, &mram).unwrap();
    assert!(t_hv < t_l2);
}

/// The typed `LifecycleError` surface (ISSUE 8 satellite): every
/// malformed trajectory is a matchable variant whose Display carries the
/// stable "lifecycle error:" prefix the CLI rows surface.
#[test]
fn lifecycle_errors_are_typed_and_displayable() {
    let mram = Mram::new();
    let mut pmu = Pmu::new();
    pmu.enter(PowerMode::ClusterActive {
        op: NOM,
        fc_util: 0.3,
        core_util: 1.0,
        hwce_active: 0.0,
    });
    let err = pmu.wake(WakeSource::Rtc, 0.0, NOM, BootPath::WarmFromL2, &mram).unwrap_err();
    assert_eq!(err, LifecycleError::WakeFromActive { mode: "cluster-active" });
    assert_eq!(err.to_string(), "lifecycle error: wake from an active mode (cluster-active)");

    let active = PowerMode::SocActive { op: NOM, fc_util: 0.5 };
    let err = Pmu::duty_cycled_power_w(active, PowerMode::DeepSleep, 2.0, 1.0).unwrap_err();
    assert_eq!(err, LifecycleError::ActiveExceedsPeriod { active_s: 2.0, period_s: 1.0 });
    assert_eq!(err.to_string(), "lifecycle error: active time 2 s exceeds period 1 s");

    let err =
        Pmu::duty_cycled_power_w(active, PowerMode::DeepSleep, f64::NAN, 1.0).unwrap_err();
    assert!(matches!(err, LifecycleError::MalformedTrace { .. }));
    assert!(err.to_string().starts_with("lifecycle error: malformed trace ("));
}
