//! PJRT runtime bridge ⇄ simulator golden checks: the JAX/Pallas
//! artifacts are the functional reference for the Rust datapaths.
//!
//! Requires `make artifacts` (skips with a notice when absent, so plain
//! `cargo test` works in a fresh checkout).

use vega::common::Rng;
use vega::hwce;
use vega::runtime::{Runtime, Tensor};

fn runtime() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping runtime tests: no artifacts at {dir:?} (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(dir).expect("artifact compilation"))
}

fn rand_i8(rng: &mut Rng, n: usize, lim: i64) -> Vec<i8> {
    (0..n).map(|_| rng.range_i64(-lim, lim) as i8).collect()
}

#[test]
fn manifest_has_all_entries() {
    let Some(rt) = runtime() else { return };
    for name in ["matmul_int8_64", "hwce_conv3x3_16", "repvgg_block_16", "mbv2_bottleneck_14"] {
        assert!(rt.signature(name).is_some(), "missing {name}");
    }
}

#[test]
fn iss_matmul_matches_pallas_artifact() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(7);
    let a = rand_i8(&mut rng, 64 * 64, 127);
    let b = rand_i8(&mut rng, 64 * 64, 127);
    let outs = rt
        .execute("matmul_int8_64", &[Tensor::I8(a.clone()), Tensor::I8(b.clone())])
        .expect("execute");
    let want = outs[0].as_i32().unwrap();

    // Simulator path: B transposed to the kernel's column-major layout.
    let av: Vec<i32> = a.iter().map(|&v| v as i32).collect();
    let mut bt = vec![0i32; 64 * 64];
    for r in 0..64 {
        for c in 0..64 {
            bt[c * 64 + r] = b[r * 64 + c] as i32;
        }
    }
    let mut cl = vega::cluster::Cluster::new();
    let mut l2 = vega::iss::FlatMem::new(vega::cluster::L2_BASE, 4096);
    let (got, kr) = vega::kernels::int_matmul::run(
        &mut cl,
        &mut l2,
        &av,
        &bt,
        64,
        64,
        64,
        vega::kernels::int_matmul::IntWidth::I8,
        8,
    );
    assert_eq!(&got, want, "ISS vs Pallas divergence");
    assert!(kr.stats.mac_per_cycle() > 13.0);
}

#[test]
fn hwce_conv_matches_pallas_artifact() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(8);
    let x = rand_i8(&mut rng, 18 * 18 * 16, 127);
    let w = rand_i8(&mut rng, 9 * 16 * 16, 127);
    let outs = rt
        .execute("hwce_conv3x3_16", &[Tensor::I8(x.clone()), Tensor::I8(w.clone())])
        .expect("execute");
    let want = outs[0].as_i32().unwrap();
    let xi: Vec<i32> = x.iter().map(|&v| v as i32).collect();
    let wi: Vec<i32> = w.iter().map(|&v| v as i32).collect();
    let got = hwce::conv3x3(&xi, &wi, 16, 16, 16, 16, hwce::Precision::Int8);
    assert_eq!(&got, want, "HWCE datapath vs Pallas divergence");
}

#[test]
fn repvgg_block_matches_hwce_plus_requant() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(9);
    let x = rand_i8(&mut rng, 18 * 18 * 16, 127);
    let w = rand_i8(&mut rng, 9 * 16 * 16, 127);
    let outs = rt
        .execute("repvgg_block_16", &[Tensor::I8(x.clone()), Tensor::I8(w.clone())])
        .expect("execute");
    let want = outs[0].as_i8().unwrap();
    let xi: Vec<i32> = x.iter().map(|&v| v as i32).collect();
    let wi: Vec<i32> = w.iter().map(|&v| v as i32).collect();
    // repvgg_block = conv3x3 -> shift 7 -> ReLU-clip to int8.
    let acc = hwce::conv3x3(&xi, &wi, 16, 16, 16, 16, hwce::Precision::Int8);
    let got: Vec<i8> = acc.iter().map(|&a| (a >> 7).clamp(0, 127) as i8).collect();
    assert_eq!(got, want, "requantised RepVGG block divergence");
}

#[test]
fn mbv2_bottleneck_executes_with_expected_shape() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(10);
    let inputs = vec![
        Tensor::I8(rand_i8(&mut rng, 14 * 14 * 24, 8)),
        Tensor::I8(rand_i8(&mut rng, 24 * 96, 8)),
        Tensor::I8(rand_i8(&mut rng, 9 * 96, 8)),
        Tensor::I8(rand_i8(&mut rng, 96 * 24, 8)),
    ];
    let outs = rt.execute("mbv2_bottleneck_14", &inputs).expect("execute");
    assert_eq!(outs[0].len(), 14 * 14 * 24);
}

#[test]
fn input_validation_rejects_bad_shapes() {
    let Some(rt) = runtime() else { return };
    let err = rt.execute("matmul_int8_64", &[Tensor::I8(vec![0; 3])]);
    assert!(err.is_err());
    let err = rt.execute(
        "matmul_int8_64",
        &[Tensor::I8(vec![0; 64 * 64]), Tensor::I32(vec![0; 64 * 64])],
    );
    assert!(err.is_err(), "dtype mismatch must be rejected");
}
