//! Minimal timing harness shared by the bench targets (criterion is
//! unavailable offline — DESIGN.md §5). Reports min/mean over N runs on
//! stdout and, via [`Bench::finish`], as machine-readable
//! `BENCH_<group>.json` so the perf trajectory is tracked across PRs
//! instead of living only in bench logs.
//!
//! `VEGA_BENCH_ITERS` overrides every case's iteration count (the CI
//! smoke run uses `VEGA_BENCH_ITERS=1`).

use std::cell::RefCell;
use std::time::Instant;

struct CaseResult {
    case: String,
    iters: u32,
    min_ms: f64,
    mean_ms: f64,
}

pub struct Bench {
    pub name: &'static str,
    results: RefCell<Vec<CaseResult>>,
    metrics: RefCell<Vec<(String, f64)>>,
}

impl Bench {
    pub fn new(name: &'static str) -> Self {
        println!("\n### bench group: {name}");
        Self { name, results: RefCell::new(Vec::new()), metrics: RefCell::new(Vec::new()) }
    }

    /// Minimum recorded time of a finished case (derived metrics such as
    /// in-run speedups are computed from these).
    #[allow(dead_code)]
    pub fn min_ms(&self, case: &str) -> Option<f64> {
        self.results.borrow().iter().find(|r| r.case == case).map(|r| r.min_ms)
    }

    /// Record a named scalar (written into the JSON `metrics` object —
    /// e.g. the sweep bench's in-run speedups).
    #[allow(dead_code)]
    pub fn metric(&self, name: &str, value: f64) {
        println!("{:<40} metric {name} = {value:.3}", self.name);
        self.metrics.borrow_mut().push((name.to_string(), value));
    }

    /// Time `f` over `iters` runs (after one warm-up) and print stats.
    pub fn run<T>(&self, case: &str, iters: u32, mut f: impl FnMut() -> T) {
        let iters = std::env::var("VEGA_BENCH_ITERS")
            .ok()
            .and_then(|s| s.parse::<u32>().ok())
            .filter(|&n| n > 0)
            .unwrap_or(iters);
        std::hint::black_box(f()); // warm-up (also primes lazy calibrations)
        let mut times = Vec::with_capacity(iters as usize);
        for _ in 0..iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let mean: f64 = times.iter().sum::<f64>() / times.len() as f64;
        println!(
            "{:<40} iters={iters:<3} min={:>10.3} ms  mean={:>10.3} ms",
            format!("{}/{case}", self.name),
            min * 1e3,
            mean * 1e3
        );
        self.results.borrow_mut().push(CaseResult {
            case: case.to_string(),
            iters,
            min_ms: min * 1e3,
            mean_ms: mean * 1e3,
        });
    }

    /// Write `BENCH_<group>.json` into the current directory (the crate
    /// root under `cargo bench`). Hand-rolled JSON: serde is unavailable
    /// offline, and the schema is four scalar fields per case.
    pub fn finish(&self) {
        let results = self.results.borrow();
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"group\": \"{}\",\n", self.name));
        s.push_str("  \"cases\": [\n");
        for (i, r) in results.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"case\": \"{}\", \"iters\": {}, \"min_ms\": {:.6}, \"mean_ms\": {:.6}}}{}\n",
                r.case,
                r.iters,
                r.min_ms,
                r.mean_ms,
                if i + 1 < results.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]");
        let metrics = self.metrics.borrow();
        if !metrics.is_empty() {
            s.push_str(",\n  \"metrics\": {\n");
            for (i, (k, v)) in metrics.iter().enumerate() {
                s.push_str(&format!(
                    "    \"{k}\": {v:.6}{}\n",
                    if i + 1 < metrics.len() { "," } else { "" }
                ));
            }
            s.push_str("  }");
        }
        s.push_str("\n}\n");
        let path = format!("BENCH_{}.json", self.name);
        match std::fs::write(&path, s) {
            Ok(()) => println!("\nwrote {path}"),
            Err(e) => eprintln!("\nfailed to write {path}: {e}"),
        }
    }
}
