//! Minimal timing harness shared by the bench targets (criterion is
//! unavailable offline — DESIGN.md §5). Reports min/mean over N runs.

use std::time::Instant;

pub struct Bench {
    pub name: &'static str,
}

impl Bench {
    pub fn new(name: &'static str) -> Self {
        println!("\n### bench group: {name}");
        Self { name }
    }

    /// Time `f` over `iters` runs (after one warm-up) and print stats.
    pub fn run<T>(&self, case: &str, iters: u32, mut f: impl FnMut() -> T) {
        std::hint::black_box(f()); // warm-up (also primes lazy calibrations)
        let mut times = Vec::with_capacity(iters as usize);
        for _ in 0..iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let mean: f64 = times.iter().sum::<f64>() / times.len() as f64;
        println!(
            "{:<40} iters={iters:<3} min={:>10.3} ms  mean={:>10.3} ms",
            format!("{}/{case}", self.name),
            min * 1e3,
            mean * 1e3
        );
    }
}
