//! Suite-level sweep-engine benchmark (§Perf): wall-clock of the full
//! `vega repro all` reproduction under the three engine configurations,
//! so `BENCH_sweeps.json` carries the in-run speedups across PRs:
//!
//! * `repro_all_serial_nocache` — one worker, memoization off (the
//!   pre-engine baseline: every report re-simulates everything);
//! * `repro_all_serial_cached`  — one worker, memoization on (what the
//!   cache alone buys: each distinct program simulates once per run);
//! * `repro_all_parallel`      — `VEGA_JOBS` (or all-core) workers plus
//!   the cache (the `vega repro all --jobs N` configuration).
//!
//! A fresh engine is built per iteration so the cache never carries over
//! between timed runs. `VEGA_BENCH_ITERS` overrides the iteration count
//! (the CI smoke uses 1). Determinism is asserted alongside the timing:
//! all three configurations must produce identical bytes.

mod harness;

use harness::Bench;
use vega::bench;
use vega::sweep::{default_jobs, SweepEngine};

fn main() {
    let b = Bench::new("sweeps");
    let jobs = default_jobs().max(2);

    // Each closure keeps its last rendered suite so the determinism
    // assertion below reuses the timed runs instead of re-running the
    // whole suite three more times.
    let (mut nocache, mut cached, mut parallel) = (String::new(), String::new(), String::new());
    b.run("repro_all_serial_nocache", 3, || {
        nocache = bench::run_all(&SweepEngine::without_cache(1));
        nocache.len()
    });
    b.run("repro_all_serial_cached", 3, || {
        cached = bench::run_all(&SweepEngine::new(1));
        cached.len()
    });
    b.run("repro_all_parallel", 3, || {
        parallel = bench::run_all(&SweepEngine::new(jobs));
        parallel.len()
    });

    // The determinism invariant, asserted on the real suite output.
    assert_eq!(nocache, cached, "memoization changed report bytes");
    assert_eq!(cached, parallel, "parallel fan-out changed report bytes");

    // In-run speedups, derived from the recorded minima.
    if let (Some(nc), Some(c), Some(p)) = (
        b.min_ms("repro_all_serial_nocache"),
        b.min_ms("repro_all_serial_cached"),
        b.min_ms("repro_all_parallel"),
    ) {
        b.metric("jobs", jobs as f64);
        b.metric("memoization_speedup_x", nc / c);
        b.metric("parallel_speedup_x", c / p);
        b.metric("total_speedup_x", nc / p);
    }

    b.finish();
}
