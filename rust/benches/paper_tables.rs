//! `cargo bench` target regenerating every paper *table* end-to-end and
//! timing the regeneration (the content itself is printed by
//! `vega repro <id>` and asserted by `rust/tests/paper_anchors.rs`).
//!
//! Each timed iteration runs on a fresh serial in-memory engine:
//! `bench::run` now routes through the process-wide cached engine (which
//! would make every iteration after the first a cache read), and what
//! this target tracks is the *uncached* per-report cost. Suite-level
//! cached/parallel timings live in `cargo bench --bench sweeps`.

mod harness;

use harness::Bench;
use vega::sweep::SweepEngine;

fn main() {
    let b = Bench::new("paper_tables");
    // Table III/IV are static; included for completeness of the sweep.
    for id in ["table1", "table2", "table3", "table4", "table5", "table6", "table7", "table8"]
    {
        b.run(id, 3, || {
            vega::bench::run_with(id, &SweepEngine::serial()).expect("known id").len()
        });
    }
    // Print the actual reports once so `cargo bench` output doubles as a
    // full reproduction record (captured into bench_output.txt).
    for id in ["table1", "table5", "table6", "table7", "table8"] {
        println!("\n{}", vega::bench::run(id).unwrap());
    }
    b.finish();
}
