//! `cargo bench` target regenerating every paper *figure* series.
//!
//! Timed iterations use a fresh serial in-memory engine per call (see
//! `paper_tables.rs`: `bench::run` is globally cached now, and this
//! target tracks the uncached per-report cost).

mod harness;

use harness::Bench;
use vega::sweep::SweepEngine;

fn main() {
    let b = Bench::new("paper_figures");
    for id in ["fig6", "fig7", "fig8", "fig9", "fig10", "fig11"] {
        b.run(id, 3, || {
            vega::bench::run_with(id, &SweepEngine::serial()).expect("known id").len()
        });
    }
    for id in ["fig6", "fig7", "fig8", "fig10", "fig11"] {
        println!("\n{}", vega::bench::run(id).unwrap());
    }
    b.finish();
}
