//! `cargo bench` target regenerating every paper *figure* series.

mod harness;

use harness::Bench;

fn main() {
    let b = Bench::new("paper_figures");
    for id in ["fig6", "fig7", "fig8", "fig9", "fig10", "fig11"] {
        b.run(id, 3, || vega::bench::run(id).expect("known id").len());
    }
    for id in ["fig6", "fig7", "fig8", "fig10", "fig11"] {
        println!("\n{}", vega::bench::run(id).unwrap());
    }
    b.finish();
}
