//! Hot-path micro-benchmarks for the §Perf optimisation pass: the
//! simulator's own throughput (host wall-clock), per layer of the stack.
//! Before/after numbers are recorded in EXPERIMENTS.md §Perf, and every
//! run also lands in `BENCH_hotpath.json` for cross-PR tracking.
//!
//! The `*_refloop` cases run the same kernels on the retained
//! one-cycle-per-iteration reference scheduler, so the cycle-skip
//! speedup is measured inside a single bench run.

mod harness;

use harness::Bench;
use vega::cluster::{Cluster, SchedulerMode, L2_BASE};
use vega::common::Rng;
use vega::cwu::hypnos::perm;
use vega::dnn::{self, PipelineConfig, StorePolicy};
use vega::hwce::{conv3x3, Precision};
use vega::iss::FlatMem;
use vega::kernels::int_matmul::{self, IntWidth};
use vega::kernels::{fp_conv, fp_fft, fp_matmul::FpWidth};
use vega::mem::ecc;

fn main() {
    let b = Bench::new("hotpath");

    // One cluster + L2 reused across all ISS cases (reset() keeps the
    // backing stores; building them per run was itself a hot path).
    let mut cl = Cluster::new();
    let mut l2 = FlatMem::new(L2_BASE, 4096);

    // L3 hot path #1: the cluster cycle loop (ISS) on the PULP-NN matmul.
    let mut rng = Rng::new(1);
    let av: Vec<i32> = (0..64 * 64).map(|_| rng.range_i64(-128, 127) as i32).collect();
    let bv: Vec<i32> = (0..64 * 64).map(|_| rng.range_i64(-128, 127) as i32).collect();
    b.run("iss_matmul_64x64x64_8cores", 10, || {
        cl.reset();
        l2.reset();
        int_matmul::run(&mut cl, &mut l2, &av, &bv, 64, 64, 64, IntWidth::I8, 8)
            .1
            .stats
            .cycles
    });
    cl.scheduler = SchedulerMode::Reference;
    b.run("iss_matmul_64x64x64_8cores_refloop", 10, || {
        cl.reset();
        l2.reset();
        int_matmul::run(&mut cl, &mut l2, &av, &bv, 64, 64, 64, IntWidth::I8, 8)
            .1
            .stats
            .cycles
    });
    cl.scheduler = SchedulerMode::CycleSkip;

    // L3 hot path #1b: superblock trace replay on vs off, same kernel.
    // Single-core runs so the replayer engages on every hot loop (with
    // several cores running, windows only open while the other cores are
    // parked at a barrier). The `superblock_speedup_*` metrics below land
    // in BENCH_hotpath.json's `metrics` object for cross-PR tracking.
    cl.superblocks = true;
    b.run("iss_matmul_64x64x64_1core_sb", 10, || {
        cl.reset();
        l2.reset();
        int_matmul::run(&mut cl, &mut l2, &av, &bv, 64, 64, 64, IntWidth::I8, 1)
            .1
            .stats
            .cycles
    });
    cl.superblocks = false;
    b.run("iss_matmul_64x64x64_1core_nosb", 10, || {
        cl.reset();
        l2.reset();
        int_matmul::run(&mut cl, &mut l2, &av, &bv, 64, 64, 64, IntWidth::I8, 1)
            .1
            .stats
            .cycles
    });

    let ch = 16usize;
    let cw = 16usize;
    let cx: Vec<f32> = (0..(ch + 2) * (cw + 2)).map(|_| rng.f32_pm1()).collect();
    let ck: Vec<f32> = (0..9).map(|_| rng.f32_pm1()).collect();
    cl.superblocks = true;
    b.run("iss_conv3x3_16x16_1core_sb", 10, || {
        cl.reset();
        l2.reset();
        fp_conv::run(&mut cl, &mut l2, &cx, &ck, ch, cw, FpWidth::F32, 1).1.stats.cycles
    });
    cl.superblocks = false;
    b.run("iss_conv3x3_16x16_1core_nosb", 10, || {
        cl.reset();
        l2.reset();
        fp_conv::run(&mut cl, &mut l2, &cx, &ck, ch, cw, FpWidth::F32, 1).1.stats.cycles
    });
    cl.superblocks = vega::iss::superblock::env_default();

    for (metric, on, off) in [
        (
            "superblock_speedup_matmul_1core",
            "iss_matmul_64x64x64_1core_sb",
            "iss_matmul_64x64x64_1core_nosb",
        ),
        (
            "superblock_speedup_conv_1core",
            "iss_conv3x3_16x16_1core_sb",
            "iss_conv3x3_16x16_1core_nosb",
        ),
    ] {
        if let (Some(sb), Some(nosb)) = (b.min_ms(on), b.min_ms(off)) {
            if sb > 0.0 {
                b.metric(metric, nosb / sb);
            }
        }
    }

    // L3 hot path #2: FFT (barrier-heavy, FP-heavy).
    let x: Vec<(f32, f32)> = (0..256).map(|_| (rng.f32_pm1(), rng.f32_pm1())).collect();
    b.run("iss_fft_256_8cores", 10, || {
        cl.reset();
        l2.reset();
        fp_fft::run(&mut cl, &mut l2, &x, FpWidth::F32, 8).1.stats.cycles
    });
    cl.scheduler = SchedulerMode::Reference;
    b.run("iss_fft_256_8cores_refloop", 10, || {
        cl.reset();
        l2.reset();
        fp_fft::run(&mut cl, &mut l2, &x, FpWidth::F32, 8).1.stats.cycles
    });
    cl.scheduler = SchedulerMode::CycleSkip;

    // L3 hot path #3: HWCE functional datapath.
    let xs: Vec<i32> = (0..34 * 34 * 16).map(|_| rng.range_i64(-128, 127) as i32).collect();
    let ws: Vec<i32> = (0..9 * 16 * 16).map(|_| rng.range_i64(-128, 127) as i32).collect();
    b.run("hwce_conv_32x32x16x16", 10, || {
        conv3x3(&xs, &ws, 32, 32, 16, 16, Precision::Int8).len()
    });

    // L3 hot path #4: Hypnos IM rematerialization (permutation-bound).
    b.run("hypnos_im_map_2048b_x100", 10, || {
        let mut acc = 0u32;
        for v in 0..100u32 {
            acc ^= perm::im_map(2048, v, 16).count_ones();
        }
        acc
    });

    // L3 hot path #5: MRAM ECC encode/decode.
    b.run("ecc_roundtrip_x10000", 10, || {
        let mut acc = 0u64;
        for i in 0..10_000u64 {
            acc ^= ecc::decode(ecc::encode(i.wrapping_mul(0x9E3779B97F4A7C15))).value();
        }
        acc
    });

    // End-to-end: full MobileNetV2 pipeline model.
    let net = dnn::mobilenet_v2();
    b.run("pipeline_mobilenetv2", 10, || {
        dnn::run_network(&net, PipelineConfig::nominal_sw(StorePolicy::AllMram)).total_cycles()
    });

    b.finish();
}
